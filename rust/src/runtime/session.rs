//! Typed step sessions: the bridge between the coordinator's training
//! loop and the compiled HLO executables.
//!
//! A `Session` owns the param store and the compiled train/eval/decode
//! executables for one artifact, and marshals the flat input/output
//! signature recorded in meta.json:
//!
//!   train:  (params..., opt..., step, lr, seed, enc, dec_in, dec_tgt)
//!           -> (params'..., opt'..., loss, correct, ntok)
//!   eval:   (params..., enc, dec_in, dec_tgt) -> (loss_sum, correct, ntok)
//!   decode: (params..., enc) -> (tokens,)
//!
//! §Perf L6 split-decode contract (continuous batching): artifacts may
//! additionally ship a prefill/decode pair so serving can schedule at
//! token granularity instead of whole-sequence `decode_step` batches:
//!
//!   prefill@<b>:  (params..., state..., enc [P, b], slot_ids [P])
//!                 -> (state'...)
//!   decode_token: (params..., state..., live [S]) -> (state'..., tokens [S])
//!
//! `state...` are the meta.json `decode_state` slots (KV caches,
//! decoder position, last emitted token) with a leading slot dimension
//! `S`; they live on device across iterations (`DecodeSlots`, the same
//! PJRT-residency pattern as the §Perf L4 param cache) and are donated
//! back into each step so cache memory is updated in place. `prefill`
//! writes rows `slot_ids` (-1 = padding row) of the state from a
//! (P, b) prompt batch; `decode_token` advances every slot with
//! `live[s] == 1` by one token. EOS detection is host-side (the server
//! compares emitted tokens against the tokenizer's EOS id). When the
//! artifact ships no split HLO, `Session::has_split_decode` is false
//! and serving falls back to the monolithic `decode_step` path.
//!
//! §L8 speculative-decode contract (draft/verify serving): an artifact
//! may additionally ship a `draft` entry in meta.json naming a second,
//! cheaper artifact (the draft model — e.g. a recycled AltUp-lite
//! model per fig5, the serving-side analogue of AltUp's cheap
//! predictor) plus a fused verify executable:
//!
//!   verify@<g>:   (params..., state..., drafted [S, g], live [S])
//!                 -> (state'..., accept_len [S], correction [S])
//!   draft_accept: (dparams..., dstate..., accept_len [S],
//!                  correction [S], live [S]) -> (dstate'...)
//!
//! `verify@<g>` scores g drafted tokens per live slot in ONE fused
//! full-model step with greedy accept-prefix semantics: `accept_len[s]`
//! is the length of the longest drafted prefix identical to what
//! greedy full-model decode would have emitted, and `correction[s]` is
//! the full model's token at the first position past that prefix. The
//! main decode state advances by exactly accept_len+1 positions.
//! `draft_accept` — an executable of the DRAFT artifact — rolls the
//! draft's own slot state back to the accepted prefix and appends the
//! correction token, re-syncing the two sessions for the next round.
//! Emitting `drafted[s][..accept_len[s]]` followed by `correction[s]`
//! is therefore token-for-token identical to plain greedy decode; the
//! server truncates at EOS/dec_len exactly as on the plain path. The
//! draft model itself drafts through its ordinary split-decode
//! `decode_token` (γ cheap steps per verify).
//!
//! §Perf L9 paged decode-state contract (paged KV pool + prefix
//! cache): an artifact may declare `"paged": {"page_size": N}` in
//! meta.json and ship page-table-operand variants of the split-decode
//! entry points:
//!
//!   prefill_paged@<b>:  (params..., pstate..., enc [P, b],
//!                        slot_ids [P], page_table [P, max_pages])
//!                        -> (pstate'...)
//!   decode_token_paged: (params..., pstate..., live [S],
//!                        page_table [S, max_pages])
//!                        -> (pstate'..., tokens [S])
//!   verify_paged@<g>:   (params..., pstate..., drafted [S, g],
//!                        live [S], page_table [S, max_pages])
//!                        -> (pstate'..., accept_len [S], correction [S])
//!
//! `pstate...` are the same meta.json `decode_state` slots, but
//! allocated with a leading POOL dimension (`pool_pages` physical
//! pages of `page_size` token positions each) instead of a slot
//! dimension (`init_paged_slots`). The page table maps each slot's
//! logical page k to a physical pool row (-1 = unmapped); entries are
//! refcounted host-side (`runtime::pages`), so several slots can share
//! the physical pages of a common prompt prefix and skip the covered
//! portion of prefill (cross-request prefix caching). `max_pages` is
//! `ceil((enc_len + dec_len) / page_size)` — the worst-case logical
//! length of one request. Allocation, eviction, and prefix matching
//! are entirely host-side policy; the HLOs only ever see the resolved
//! tables. When the artifact ships no paged contract,
//! `Session::has_paged_decode` is false and serving falls back to the
//! monolithic per-slot `DecodeSlots` path with identical outputs.
//!
//! §Perf L12 tensor-parallel sharding contract: an artifact may
//! declare `"sharding": {"tp": N}` in meta.json and ship, for every
//! shard `i` in `0..N`, shard-suffixed variants of the split-serving
//! entry points — `prefill@<b>/shard<i>`, `decode_token/shard<i>`,
//! and the paged/verify families where present — compiled for a
//! head-sharded attention + column/row-split FFN partition with AltUp
//! predict/correct replicated per shard. Each shard executable keeps
//! the whole-model calling convention (same operands, same outputs;
//! the shard's partial activations are resolved by the compiled-in
//! collectives), so a `Session` bound to shard `i` via `bind_shard`
//! transparently routes every compile through the `/shard<i>` variant
//! when the manifest ships it and falls back to the whole-model
//! executable otherwise. `has_sharded_decode(tp)` gates the group
//! path: the coordinator only builds a `tp`-wide execution group when
//! the declared `sharding.tp` matches and every shard's split-decode
//! pair is present; anything else serves whole-model, unsharded.
//!
//! §Perf L4 (EXPERIMENTS.md): parameter/optimizer state is kept
//! device-resident as `PjRtBuffer`s across steps. Per train step, only
//! the batch + three scalars cross the host boundary on the way in and
//! only the three scalar metrics on the way out; the updated
//! params/opt buffers are fed straight back into the next step. The
//! host `ParamStore` is synced lazily (`sync_store` / `checkpoint`).

use crate::data::batcher::Batch;
use crate::runtime::artifact::Artifact;
use crate::runtime::client::{Client, Executable};
use crate::runtime::params::ParamStore;
use crate::runtime::tensor::Tensor;
use crate::util::lru::LruCache;
use anyhow::{bail, Context, Result};
use std::rc::Rc;
use std::time::Instant;

/// How params/opt state is held between steps (§Perf L3/L4 history in
/// EXPERIMENTS.md). Resolved from the environment once at
/// `Session::open` — the env lookups used to sit in the per-step hot
/// path (read up to twice per train step).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    /// Params/opt live on device as `PjRtBuffer`s across steps; only
    /// scalar metrics are pulled to host per step (§Perf L4, default).
    Device,
    /// §Perf L3 behavior: outputs synced to host literals every step
    /// (no device residency), but the literal -> `Tensor` -> literal
    /// round trip is skipped. A/B switch: `ALTUP_NO_DEVICE_CACHE=1`.
    HostLiteral,
    /// No caching at all: full literal -> `Tensor` -> literal round
    /// trip per step (pre-§Perf baseline). A/B switch:
    /// `ALTUP_NO_STATE_CACHE=1`.
    Off,
}

impl CacheMode {
    pub fn from_env() -> CacheMode {
        if crate::util::env::flag("ALTUP_NO_STATE_CACHE") {
            CacheMode::Off
        } else if crate::util::env::flag("ALTUP_NO_DEVICE_CACHE") {
            CacheMode::HostLiteral
        } else {
            CacheMode::Device
        }
    }
}

/// Smallest sequence-length bucket the serving path will execute.
pub const MIN_BUCKET: usize = 8;

/// The bucket ladder for a model with encoder length `enc_len`: powers
/// of two from `MIN_BUCKET` up, capped by (and always including) the
/// full `enc_len`. Short prompts run the smallest bucket that fits
/// instead of paying full-length compute (§Perf L5).
pub fn bucket_lengths(enc_len: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut b = MIN_BUCKET;
    while b < enc_len {
        out.push(b);
        b <<= 1;
    }
    out.push(enc_len);
    out
}

/// The bucket a prompt of `len` tokens lands in: the smallest ladder
/// entry >= `len`. Prompts at or beyond `enc_len` map to `enc_len`
/// (the caller flags the truncation).
pub fn bucket_for(len: usize, enc_len: usize) -> usize {
    if len >= enc_len {
        return enc_len;
    }
    let mut b = MIN_BUCKET;
    while b < enc_len {
        if len <= b {
            return b;
        }
        b <<= 1;
    }
    enc_len
}

fn bucket_cache_cap_from_env() -> usize {
    crate::util::env::usize_at_least("ALTUP_BUCKET_CACHE", 1, 8)
}

/// Bounded cache of shape-specialized executables keyed by
/// sequence-length bucket. Used for the `decode_step@<b>` and
/// `prefill@<b>` executable families; generic so the eviction policy
/// is unit-testable without compiling HLO (the offline xla stub cannot
/// produce an `Executable`).
///
/// Since §L10 the whole cache — value storage, entry cap, and the
/// §L9 shared recency policy — is the generic `util::lru::LruCache`;
/// this alias pins the key type to the sequence-length bucket. (The
/// prefix-page cache keeps composing `LruPolicy` directly: it evicts
/// on pool pressure with refcount pinning, not on entry count.)
pub type BucketLru<T> = LruCache<usize, T>;

/// Cached step state, in meta.json order.
enum CachedState {
    /// Device-resident buffers (§Perf L4). `opt` may be empty for
    /// eval-only warm caches; `train_step` fills it lazily from the
    /// host store (valid because opt only changes when a train step
    /// also bumps `store.step`).
    Device { params: Vec<xla::PjRtBuffer>, opt: Vec<xla::PjRtBuffer> },
    /// Host-literal cache (§Perf L3 fallback).
    Host { params: Vec<xla::Literal>, opt: Vec<xla::Literal> },
}

/// Device-resident continuous-batching slot state (§Perf L6): one
/// `PjRtBuffer` per `decode_state` spec with the slot dimension
/// prepended. Owned by a serving replica and threaded through
/// `Session::prefill` / `Session::decode_token`, which donate the
/// buffers into each step (the HLO aliases them into the outputs, so
/// KV-cache memory is updated in place rather than copied per token).
pub struct DecodeSlots {
    /// Slot count `S` — the leading dimension of every state buffer.
    pub slots: usize,
    state: Vec<xla::PjRtBuffer>,
}

pub struct Session {
    pub artifact: Artifact,
    pub store: ParamStore,
    train: Option<Rc<Executable>>,
    eval: Option<Rc<Executable>>,
    decode: Option<Rc<Executable>>,
    forward: Option<Rc<Executable>>,
    /// Shape-specialized decode executables keyed by sequence-length
    /// bucket (§Perf L5). Compiled lazily from the artifact's
    /// `decode_step@<bucket>` HLO; bounded by `ALTUP_BUCKET_CACHE`
    /// (default 8) with LRU eviction.
    decode_buckets: BucketLru<Rc<Executable>>,
    /// Same, for the split-serving `prefill@<bucket>` family (§Perf L6).
    prefill_buckets: BucketLru<Rc<Executable>>,
    /// The fused per-token decode executable (§Perf L6).
    decode_token: Option<Rc<Executable>>,
    /// Same as `prefill_buckets`, for the page-table-operand
    /// `prefill_paged@<bucket>` family (§L9).
    prefill_paged_buckets: BucketLru<Rc<Executable>>,
    /// The fused paged per-token decode executable (§L9).
    decode_token_paged: Option<Rc<Executable>>,
    /// The fused speculative verify executable (§L8), cached for the
    /// one draft length γ a server runs at.
    verify_exe: Option<(usize, Rc<Executable>)>,
    /// The paged variant of `verify_exe` (§L9).
    verify_paged_exe: Option<(usize, Rc<Executable>)>,
    /// The draft-side accept/rollback executable (§L8; compiled from a
    /// DRAFT artifact's `draft_accept` entry point).
    spec_accept_exe: Option<Rc<Executable>>,
    /// Params/opt cache between steps. `state_step` records the store
    /// step the cache mirrors; a mismatch (e.g. after loading a
    /// checkpoint) invalidates it.
    state: Option<CachedState>,
    state_step: u64,
    /// True when the cache holds training progress the host store has
    /// not seen yet (a clean warm-up cache never needs syncing back).
    dirty: bool,
    mode: CacheMode,
    /// Wall-clock spent inside PJRT execute (per step kind).
    pub exec_seconds: f64,
    /// Wall-clock spent marshalling host tensors <-> literals.
    pub marshal_seconds: f64,
    /// Wall-clock spent moving data across the host<->device boundary
    /// (literal uploads, buffer downloads). §Perf L4 metric.
    pub transfer_seconds: f64,
    /// §L12: when bound, every compile resolves `<kind>` to
    /// `<kind>/shard<i>` where the manifest ships that variant (see
    /// the module header sharding contract). None = whole-model.
    shard: Option<usize>,
}

#[derive(Debug, Clone, Copy)]
pub struct StepMetrics {
    pub loss: f32,
    pub correct: f32,
    pub ntok: f32,
}

impl StepMetrics {
    pub fn accuracy(&self) -> f32 {
        if self.ntok > 0.0 {
            self.correct / self.ntok
        } else {
            0.0
        }
    }
}

impl Session {
    fn new(artifact: Artifact, seed: u64) -> Session {
        let store = ParamStore::init(&artifact, seed);
        Session {
            artifact,
            store,
            train: None,
            eval: None,
            decode: None,
            forward: None,
            decode_buckets: BucketLru::new(bucket_cache_cap_from_env()),
            prefill_buckets: BucketLru::new(bucket_cache_cap_from_env()),
            decode_token: None,
            prefill_paged_buckets: BucketLru::new(bucket_cache_cap_from_env()),
            decode_token_paged: None,
            verify_exe: None,
            verify_paged_exe: None,
            spec_accept_exe: None,
            state: None,
            state_step: 0,
            dirty: false,
            mode: CacheMode::from_env(),
            exec_seconds: 0.0,
            marshal_seconds: 0.0,
            transfer_seconds: 0.0,
            shard: None,
        }
    }

    /// Load + compile the artifact's executables (lazily per kind).
    pub fn open(client: &Client, artifact: Artifact, seed: u64) -> Result<Session> {
        let mut s = Session::new(artifact, seed);
        // Compile the train step eagerly: it is the common case and we
        // want compile failures surfaced at open().
        s.train = Some(s.compile(client, "train_step")?);
        Ok(s)
    }

    /// Open for inference/eval only (no train executable).
    pub fn open_eval(_client: &Client, artifact: Artifact, seed: u64) -> Result<Session> {
        Ok(Session::new(artifact, seed))
    }

    /// Drop the cached state (call after replacing `store`).
    pub fn invalidate_state(&mut self) {
        self.state = None;
        self.dirty = false;
    }

    pub fn cache_mode(&self) -> CacheMode {
        self.mode
    }

    /// Switch caching strategy (A/B benches, coherence tests). Syncs
    /// any pending device/literal progress into the host store first so
    /// no training is lost, then drops the cache.
    pub fn set_cache_mode(&mut self, mode: CacheMode) -> Result<()> {
        self.sync_store()?;
        self.invalidate_state();
        self.mode = mode;
        Ok(())
    }

    fn state_is_fresh(&self) -> bool {
        self.state.is_some() && self.state_step == self.store.step
    }

    /// Write the cached state back into the host param store (no-op if
    /// the cache is absent, stale, or holds no unsynced progress).
    /// Must be called before reading `store.params` after training —
    /// `checkpoint()` and the eval paths do so automatically.
    pub fn sync_store(&mut self) -> Result<()> {
        if !self.state_is_fresh() || !self.dirty {
            return Ok(());
        }
        match self.state.as_ref().unwrap() {
            CachedState::Device { params, opt } => {
                // Device -> host: download buffers (transfer), then
                // convert to tensors (marshal).
                let t0 = Instant::now();
                let plits: Vec<xla::Literal> =
                    params.iter().map(|b| b.to_literal_sync()).collect::<Result<_, _>>()?;
                let olits: Vec<xla::Literal> =
                    opt.iter().map(|b| b.to_literal_sync()).collect::<Result<_, _>>()?;
                self.transfer_seconds += t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                for (i, lit) in plits.iter().enumerate() {
                    self.store.params[i] = Tensor::from_literal(lit)?;
                }
                for (i, lit) in olits.iter().enumerate() {
                    self.store.opt[i] = Tensor::from_literal(lit)?;
                }
                self.marshal_seconds += t1.elapsed().as_secs_f64();
            }
            CachedState::Host { params, opt } => {
                let t0 = Instant::now();
                for (i, lit) in params.iter().enumerate() {
                    self.store.params[i] = Tensor::from_literal(lit)?;
                }
                for (i, lit) in opt.iter().enumerate() {
                    self.store.opt[i] = Tensor::from_literal(lit)?;
                }
                self.marshal_seconds += t0.elapsed().as_secs_f64();
            }
        }
        self.dirty = false;
        Ok(())
    }

    /// Sync + save a checkpoint.
    pub fn checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.sync_store()?;
        self.store.save(path)
    }

    /// Upload the host store to device buffers ahead of time (server
    /// startup, post-checkpoint-load), so the first step/batch does not
    /// pay the cold upload. No-op unless the session runs in
    /// `CacheMode::Device`.
    pub fn warm_device_cache(&mut self, client: &Client) -> Result<()> {
        if self.mode != CacheMode::Device {
            return Ok(());
        }
        // Never discard unsynced training progress: flush a dirty cache
        // into the host store before re-uploading from it.
        self.sync_store()?;
        self.invalidate_state();
        self.ensure_device_state(client, false)
    }

    /// Make params (and optionally opt) device-resident, reusing the
    /// cache when it mirrors the store. Cold uploads are attributed to
    /// `transfer_seconds` wholesale (the steady state has none).
    fn ensure_device_state(&mut self, client: &Client, need_opt: bool) -> Result<()> {
        let fresh =
            self.state_step == self.store.step && matches!(self.state, Some(CachedState::Device { .. }));
        let t0 = Instant::now();
        if !fresh {
            let params = upload_all(client, &self.store.params)?;
            let opt =
                if need_opt { upload_all(client, &self.store.opt)? } else { Vec::new() };
            self.state = Some(CachedState::Device { params, opt });
            self.state_step = self.store.step;
            self.dirty = false;
        } else if need_opt {
            let opt_missing = !self.store.opt.is_empty()
                && matches!(&self.state, Some(CachedState::Device { opt, .. }) if opt.is_empty());
            if opt_missing {
                let uploaded = upload_all(client, &self.store.opt)?;
                if let Some(CachedState::Device { opt, .. }) = &mut self.state {
                    *opt = uploaded;
                }
            }
        }
        self.transfer_seconds += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Bind this session to shard `shard` of a §L12 execution group:
    /// subsequent compiles prefer the `<kind>/shard<i>` manifest
    /// entries. Call before any serving executable is compiled so the
    /// whole split-decode family resolves shard-side.
    pub fn bind_shard(&mut self, shard: usize) {
        self.shard = Some(shard);
    }

    /// §L12 shard routing: the `<kind>/shard<i>` variant when this
    /// session is bound to a shard and the manifest ships it; the
    /// whole-model `kind` otherwise (automatic fallback — identical
    /// outputs by the sharding contract).
    fn shard_kind(&self, kind: &str) -> String {
        if let Some(s) = self.shard {
            let sharded = format!("{kind}/shard{s}");
            if self.artifact.has(&sharded) {
                return sharded;
            }
        }
        kind.to_string()
    }

    fn compile(&self, client: &Client, kind: &str) -> Result<Rc<Executable>> {
        let kind = self.shard_kind(kind);
        let key = format!("{}:{}", self.artifact.name, kind);
        client.compile_hlo(&key, self.artifact.hlo_path(&kind)?)
    }

    pub fn ensure_eval(&mut self, client: &Client) -> Result<()> {
        if self.eval.is_none() {
            self.eval = Some(self.compile(client, "eval_step")?);
        }
        Ok(())
    }
    pub fn ensure_decode(&mut self, client: &Client) -> Result<()> {
        if self.decode.is_none() {
            self.decode = Some(self.compile(client, "decode_step")?);
        }
        Ok(())
    }
    pub fn ensure_forward(&mut self, client: &Client) -> Result<()> {
        if self.forward.is_none() {
            self.forward = Some(self.compile(client, "forward")?);
        }
        Ok(())
    }

    fn batch_literals(&self, batch: &Batch) -> Result<Vec<xla::Literal>> {
        let cfg = &self.artifact.config;
        if batch.enc_tokens.len() != cfg.batch_size * cfg.enc_len {
            bail!(
                "batch enc size {} != {}x{}",
                batch.enc_tokens.len(),
                cfg.batch_size,
                cfg.enc_len
            );
        }
        let enc = Tensor::i32(vec![cfg.batch_size, cfg.enc_len], batch.enc_tokens.clone());
        let dec_in = Tensor::i32(vec![cfg.batch_size, cfg.dec_len], batch.dec_input.clone());
        let dec_tgt = Tensor::i32(vec![cfg.batch_size, cfg.dec_len], batch.dec_targets.clone());
        Ok(vec![enc.to_literal()?, dec_in.to_literal()?, dec_tgt.to_literal()?])
    }

    /// One optimizer step. In `CacheMode::Device` the params/opt stay
    /// on device between steps (§Perf L4) and only the batch + scalars
    /// go up / the 3 metric scalars come down; the host store is
    /// synced lazily via `sync_store()` / `checkpoint()`.
    pub fn train_step(
        &mut self,
        client: &Client,
        lr: f32,
        seed: u32,
        batch: &Batch,
    ) -> Result<StepMetrics> {
        let exe = Rc::clone(self.train.as_ref().context("train exe not compiled")?);
        match self.mode {
            CacheMode::Device => self.train_step_device(client, exe, lr, seed, batch),
            CacheMode::HostLiteral | CacheMode::Off => {
                self.train_step_host(exe, lr, seed, batch)
            }
        }
    }

    fn train_step_device(
        &mut self,
        client: &Client,
        exe: Rc<Executable>,
        lr: f32,
        seed: u32,
        batch: &Batch,
    ) -> Result<StepMetrics> {
        let np = self.store.params.len();
        let no = self.store.opt.len();

        // Host-side marshalling: only the scalars + batch (small).
        let t0 = Instant::now();
        let step_f = (self.store.step + 1) as f32;
        let mut small: Vec<xla::Literal> = Vec::with_capacity(6);
        small.push(Tensor::scalar_f32(step_f).to_literal()?);
        small.push(Tensor::scalar_f32(lr).to_literal()?);
        small.push(Tensor::scalar_u32(seed).to_literal()?);
        small.extend(self.batch_literals(batch)?);
        self.marshal_seconds += t0.elapsed().as_secs_f64();

        // Device residency: params/opt reused from cache (no traffic in
        // the steady state); batch + scalars uploaded fresh each step.
        self.ensure_device_state(client, true)?;
        let t1 = Instant::now();
        let small_bufs: Vec<xla::PjRtBuffer> =
            small.iter().map(|l| client.upload(l)).collect::<Result<_>>()?;
        self.transfer_seconds += t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        let outs = {
            let Some(CachedState::Device { params, opt }) = self.state.as_ref() else {
                bail!("device state missing after ensure_device_state");
            };
            let refs: Vec<&xla::PjRtBuffer> =
                params.iter().chain(opt.iter()).chain(small_bufs.iter()).collect();
            exe.run_buffers(&refs)?
        };
        self.exec_seconds += t2.elapsed().as_secs_f64();

        if outs.len() != np + no + 3 {
            bail!("train_step returned {} outputs, expected {}", outs.len(), np + no + 3);
        }
        // Outputs stay device-resident: params'/opt' become the next
        // step's inputs without touching the host.
        let mut params_new = outs;
        let metrics = params_new.split_off(np + no);
        let opt_new = params_new.split_off(np);
        self.state = Some(CachedState::Device { params: params_new, opt: opt_new });
        self.store.step += 1;
        self.state_step = self.store.step;
        self.dirty = true;

        // Targeted download: just the three scalar metrics.
        let t3 = Instant::now();
        let loss_lit = metrics[0].to_literal_sync()?;
        let correct_lit = metrics[1].to_literal_sync()?;
        let ntok_lit = metrics[2].to_literal_sync()?;
        self.transfer_seconds += t3.elapsed().as_secs_f64();
        Ok(StepMetrics {
            loss: Tensor::from_literal(&loss_lit)?.as_f32()?[0],
            correct: Tensor::from_literal(&correct_lit)?.as_f32()?[0],
            ntok: Tensor::from_literal(&ntok_lit)?.as_f32()?[0],
        })
    }

    /// §Perf L3 literal-cache path (`CacheMode::HostLiteral`) and the
    /// uncached A/B baseline (`CacheMode::Off`).
    fn train_step_host(
        &mut self,
        exe: Rc<Executable>,
        lr: f32,
        seed: u32,
        batch: &Batch,
    ) -> Result<StepMetrics> {
        let np = self.store.params.len();
        let no = self.store.opt.len();

        let t0 = Instant::now();
        let use_cache = self.mode == CacheMode::HostLiteral
            && self.state_is_fresh()
            && matches!(self.state, Some(CachedState::Host { .. }));
        let mut scratch: Vec<xla::Literal> =
            Vec::with_capacity(if use_cache { 6 } else { np + no + 6 });
        if !use_cache {
            for t in &self.store.params {
                scratch.push(t.to_literal()?);
            }
            for t in &self.store.opt {
                scratch.push(t.to_literal()?);
            }
        }
        let step_f = (self.store.step + 1) as f32;
        scratch.push(Tensor::scalar_f32(step_f).to_literal()?);
        scratch.push(Tensor::scalar_f32(lr).to_literal()?);
        scratch.push(Tensor::scalar_u32(seed).to_literal()?);
        scratch.extend(self.batch_literals(batch)?);
        let refs: Vec<&xla::Literal> = if use_cache {
            let Some(CachedState::Host { params, opt }) = self.state.as_ref() else {
                bail!("host literal cache missing");
            };
            params.iter().chain(opt.iter()).chain(scratch.iter()).collect()
        } else {
            scratch.iter().collect()
        };
        self.marshal_seconds += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let mut outs = exe.run(&refs)?;
        self.exec_seconds += t1.elapsed().as_secs_f64();
        drop(refs);

        if outs.len() != np + no + 3 {
            bail!("train_step returned {} outputs, expected {}", outs.len(), np + no + 3);
        }
        let t2 = Instant::now();
        let metrics = outs.split_off(np + no);
        let opt_lits = outs.split_off(np);
        if self.mode == CacheMode::Off {
            // A/B baseline: full host round-trip every step.
            for (i, lit) in outs.iter().enumerate() {
                self.store.params[i] = Tensor::from_literal(lit)?;
            }
            for (i, lit) in opt_lits.iter().enumerate() {
                self.store.opt[i] = Tensor::from_literal(lit)?;
            }
            self.state = None;
            self.dirty = false;
        } else {
            self.state = Some(CachedState::Host { params: outs, opt: opt_lits });
            self.dirty = true;
        }
        self.store.step += 1;
        self.state_step = self.store.step;
        self.marshal_seconds += t2.elapsed().as_secs_f64();
        let loss = Tensor::from_literal(&metrics[0])?.as_f32()?[0];
        let correct = Tensor::from_literal(&metrics[1])?.as_f32()?[0];
        let ntok = Tensor::from_literal(&metrics[2])?.as_f32()?[0];
        Ok(StepMetrics { loss, correct, ntok })
    }

    /// Run an executable with `params... + extra` inputs, keeping the
    /// parameters device-resident (or literal-cached) when fresh.
    fn run_with_params(
        &mut self,
        client: &Client,
        exe: Rc<Executable>,
        extra: Vec<xla::Literal>,
    ) -> Result<Vec<xla::Literal>> {
        if self.mode == CacheMode::Device {
            self.ensure_device_state(client, false)?;
            let t0 = Instant::now();
            let extra_bufs: Vec<xla::PjRtBuffer> =
                extra.iter().map(|l| client.upload(l)).collect::<Result<_>>()?;
            self.transfer_seconds += t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let out_bufs = {
                let Some(CachedState::Device { params, .. }) = self.state.as_ref() else {
                    bail!("device state missing after ensure_device_state");
                };
                let refs: Vec<&xla::PjRtBuffer> =
                    params.iter().chain(extra_bufs.iter()).collect();
                exe.run_buffers(&refs)?
            };
            self.exec_seconds += t1.elapsed().as_secs_f64();
            let t2 = Instant::now();
            let outs: Vec<xla::Literal> =
                out_bufs.iter().map(|b| b.to_literal_sync()).collect::<Result<_, _>>()?;
            self.transfer_seconds += t2.elapsed().as_secs_f64();
            return Ok(outs);
        }

        // Host paths: reuse the literal cache when fresh, else upload
        // from the store.
        let use_cache = self.mode == CacheMode::HostLiteral
            && self.state_is_fresh()
            && matches!(self.state, Some(CachedState::Host { .. }));
        let scratch: Vec<xla::Literal> = if use_cache {
            Vec::new()
        } else {
            let t0 = Instant::now();
            let lits: Result<Vec<xla::Literal>> =
                self.store.params.iter().map(|t| t.to_literal()).collect();
            self.marshal_seconds += t0.elapsed().as_secs_f64();
            lits?
        };
        let refs: Vec<&xla::Literal> = if use_cache {
            let Some(CachedState::Host { params, .. }) = self.state.as_ref() else {
                bail!("host literal cache missing");
            };
            params.iter().chain(extra.iter()).collect()
        } else {
            scratch.iter().chain(extra.iter()).collect()
        };
        let t1 = Instant::now();
        let outs = exe.run(&refs)?;
        self.exec_seconds += t1.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Teacher-forced eval on one batch (sums, not means).
    pub fn eval_step(&mut self, client: &Client, batch: &Batch) -> Result<StepMetrics> {
        self.ensure_eval(client)?;
        let exe = Rc::clone(self.eval.as_ref().unwrap());
        let extra = self.batch_literals(batch)?;
        let outs = self.run_with_params(client, exe, extra)?;
        Ok(StepMetrics {
            loss: Tensor::from_literal(&outs[0])?.as_f32()?[0],
            correct: Tensor::from_literal(&outs[1])?.as_f32()?[0],
            ntok: Tensor::from_literal(&outs[2])?.as_f32()?[0],
        })
    }

    /// Greedy decode: (B, enc_len) token ids -> (B, dec_len) outputs.
    pub fn decode(&mut self, client: &Client, enc_tokens: &[i32]) -> Result<Vec<Vec<i32>>> {
        self.ensure_decode(client)?;
        let cfg = self.artifact.config.clone();
        if enc_tokens.len() != cfg.batch_size * cfg.enc_len {
            bail!("decode batch must be exactly (batch_size, enc_len)");
        }
        let exe = Rc::clone(self.decode.as_ref().unwrap());
        let extra = vec![
            Tensor::i32(vec![cfg.batch_size, cfg.enc_len], enc_tokens.to_vec()).to_literal()?,
        ];
        let outs = self.run_with_params(client, exe, extra)?;
        let t = Tensor::from_literal(&outs[0])?;
        let data = t.as_i32()?;
        Ok(data.chunks(cfg.dec_len).map(|c| c.to_vec()).collect())
    }

    /// The sequence length a `decode_bucketed(bucket)` call actually
    /// executes at: `bucket` itself when the artifact ships a
    /// shape-specialized `decode_step@<bucket>` HLO (or `bucket` is
    /// already the full length), else the full `enc_len` fallback.
    /// Serving-side padded-token accounting must use this value.
    pub fn effective_bucket(&self, bucket: usize) -> usize {
        let enc_len = self.artifact.config.enc_len;
        if bucket >= enc_len {
            enc_len
        } else if self.artifact.has(&format!("decode_step@{bucket}")) {
            bucket
        } else {
            enc_len
        }
    }

    /// Look up (or lazily compile) the decode executable for one
    /// sequence-length bucket, LRU-bounded by `ALTUP_BUCKET_CACHE`.
    /// Each eviction releases the client's cache entry exactly once
    /// (`BucketLru::insert` hands every evicted entry back once).
    fn bucket_exe(&mut self, client: &Client, bucket: usize) -> Result<Rc<Executable>> {
        if let Some(exe) = self.decode_buckets.get(bucket) {
            return Ok(Rc::clone(exe));
        }
        let exe = self.compile(client, &format!("decode_step@{bucket}"))?;
        for (evicted, _) in self.decode_buckets.insert(bucket, Rc::clone(&exe)) {
            let kind = self.shard_kind(&format!("decode_step@{evicted}"));
            client.evict(&format!("{}:{}", self.artifact.name, kind));
        }
        Ok(exe)
    }

    /// Same policy for the `prefill@<bucket>` family.
    fn prefill_exe(&mut self, client: &Client, bucket: usize) -> Result<Rc<Executable>> {
        if let Some(exe) = self.prefill_buckets.get(bucket) {
            return Ok(Rc::clone(exe));
        }
        let exe = self.compile(client, &format!("prefill@{bucket}"))?;
        for (evicted, _) in self.prefill_buckets.insert(bucket, Rc::clone(&exe)) {
            let kind = self.shard_kind(&format!("prefill@{evicted}"));
            client.evict(&format!("{}:{}", self.artifact.name, kind));
        }
        Ok(exe)
    }

    /// Number of bucketed decode executables currently cached.
    pub fn bucket_cache_len(&self) -> usize {
        self.decode_buckets.len()
    }

    /// Greedy decode of a batch packed at `bucket` stride: `enc_tokens`
    /// is (batch_size, bucket) row-major. Runs the bucket's
    /// shape-specialized executable when the artifact provides one;
    /// otherwise re-pads to the full (batch_size, enc_len) geometry and
    /// runs the full-length decode, so results are identical either
    /// way (zero right-padding is the decode contract).
    pub fn decode_bucketed(
        &mut self,
        client: &Client,
        enc_tokens: &[i32],
        bucket: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let cfg = self.artifact.config.clone();
        if bucket == cfg.enc_len {
            return self.decode(client, enc_tokens);
        }
        if bucket > cfg.enc_len {
            bail!("bucket {bucket} exceeds enc_len {}", cfg.enc_len);
        }
        if enc_tokens.len() != cfg.batch_size * bucket {
            bail!(
                "bucketed decode batch size {} != {}x{bucket}",
                enc_tokens.len(),
                cfg.batch_size
            );
        }
        if self.effective_bucket(bucket) != bucket {
            // No shape-specialized HLO for this bucket: re-pad each row
            // out to the full length and run the full-geometry decode.
            let mut full = vec![0i32; cfg.batch_size * cfg.enc_len];
            for (i, row) in enc_tokens.chunks(bucket).enumerate() {
                full[i * cfg.enc_len..i * cfg.enc_len + bucket].copy_from_slice(row);
            }
            return self.decode(client, &full);
        }
        let exe = self.bucket_exe(client, bucket)?;
        let extra = vec![
            Tensor::i32(vec![cfg.batch_size, bucket], enc_tokens.to_vec()).to_literal()?,
        ];
        let outs = self.run_with_params(client, exe, extra)?;
        let t = Tensor::from_literal(&outs[0])?;
        let data = t.as_i32()?;
        Ok(data.chunks(cfg.dec_len).map(|c| c.to_vec()).collect())
    }

    // ----- §Perf L6: split prefill/decode_token serving path -----

    /// True when the artifact ships the split-decode executable pair
    /// (see the module header for the contract): a `decode_token` HLO,
    /// a full-length prefill entry point (`prefill`, or equivalently
    /// `prefill@<enc_len>` — every prompt can land in the `enc_len`
    /// bucket, so sub-bucket-only prefill cannot serve the workload),
    /// and the `decode_state` slot specs the runtime needs to allocate
    /// the device-resident KV cache.
    pub fn has_split_decode(&self) -> bool {
        if !self.artifact.has("decode_token") || self.artifact.decode_state.is_empty() {
            return false;
        }
        self.artifact.has("prefill")
            || self.artifact.has(&format!("prefill@{}", self.artifact.config.enc_len))
    }

    /// True when this artifact can serve as a `tp`-wide §L12 execution
    /// group: the meta.json `sharding.tp` matches the requested width,
    /// the whole-model split-decode pair is present (the fallback path
    /// and the source of `decode_state` geometry), and every shard in
    /// `0..tp` ships its own `decode_token/shard<i>` plus a full-length
    /// prefill variant. Any mismatch degrades to whole-model serving
    /// rather than erroring — sharding is an optimization, not a new
    /// output contract.
    pub fn has_sharded_decode(&self, tp: usize) -> bool {
        if tp < 2 || self.artifact.sharding.as_ref().map(|s| s.tp) != Some(tp) {
            return false;
        }
        if !self.has_split_decode() {
            return false;
        }
        let enc_len = self.artifact.config.enc_len;
        (0..tp).all(|i| {
            self.artifact.has(&format!("decode_token/shard{i}"))
                && (self.artifact.has(&format!("prefill/shard{i}"))
                    || self.artifact.has(&format!("prefill@{enc_len}/shard{i}")))
        })
    }

    /// The sequence length a `prefill(bucket)` call actually executes
    /// at: `bucket` when a shape-specialized `prefill@<bucket>` HLO
    /// exists, else the full `enc_len` (served by the generic
    /// `prefill` entry point).
    pub fn effective_prefill_bucket(&self, bucket: usize) -> usize {
        let enc_len = self.artifact.config.enc_len;
        if bucket < enc_len && self.artifact.has(&format!("prefill@{bucket}")) {
            bucket
        } else {
            enc_len
        }
    }

    /// Allocate the device-resident slot state for `slots` concurrent
    /// requests: one zeroed buffer per `decode_state` spec with the
    /// slot dimension prepended. The buffers never leave the device;
    /// `prefill`/`decode_token` donate them back into each step.
    pub fn init_decode_slots(&mut self, client: &Client, slots: usize) -> Result<DecodeSlots> {
        if !self.has_split_decode() {
            bail!(
                "artifact {} ships no split-decode HLO (prefill/decode_token + decode_state)",
                self.artifact.name
            );
        }
        let t0 = Instant::now();
        let mut state = Vec::with_capacity(self.artifact.decode_state.len());
        for spec in &self.artifact.decode_state {
            let mut shape = vec![slots];
            shape.extend_from_slice(&spec.shape);
            // Allocate at the spec's dtype: KV caches are f32 but
            // position/last-token slots are i32, and PJRT rejects
            // dtype-mismatched arguments.
            let n: usize = shape.iter().product();
            let zeros = match spec.dtype {
                crate::runtime::tensor::DType::F32 => Tensor::zeros_f32(shape),
                crate::runtime::tensor::DType::I32 => Tensor::i32(shape, vec![0; n]),
                crate::runtime::tensor::DType::U32 => Tensor::u32(shape, vec![0; n]),
            };
            state.push(client.upload(&zeros.to_literal()?)?);
        }
        self.transfer_seconds += t0.elapsed().as_secs_f64();
        Ok(DecodeSlots { slots, state })
    }

    /// Prefill a (P, bucket) prompt batch into slot rows `slot_ids`
    /// (-1 marks a padding row), consuming and returning the slot
    /// state. Runs the bucket's shape-specialized prefill when the
    /// artifact ships one; otherwise re-pads to the full `enc_len`
    /// geometry — outputs are identical either way (zero right-padding
    /// is the decode contract).
    pub fn prefill(
        &mut self,
        client: &Client,
        slots: DecodeSlots,
        enc_tokens: &[i32],
        bucket: usize,
        slot_ids: &[i32],
    ) -> Result<DecodeSlots> {
        if self.mode != CacheMode::Device {
            bail!("split decode requires CacheMode::Device (serving default)");
        }
        let enc_len = self.artifact.config.enc_len;
        if bucket > enc_len {
            bail!("prefill bucket {bucket} exceeds enc_len {enc_len}");
        }
        if enc_tokens.len() != slot_ids.len() * bucket {
            bail!(
                "prefill batch size {} != {}x{bucket}",
                enc_tokens.len(),
                slot_ids.len()
            );
        }
        let eff = self.effective_prefill_bucket(bucket);
        let (exe, enc_owned);
        if eff == bucket && bucket < enc_len {
            exe = self.prefill_exe(client, bucket)?;
            enc_owned = enc_tokens.to_vec();
        } else {
            exe = self.compile_prefill_full(client)?;
            let rows = slot_ids.len();
            let mut full = vec![0i32; rows * enc_len];
            for (i, row) in enc_tokens.chunks(bucket).enumerate() {
                full[i * enc_len..i * enc_len + bucket].copy_from_slice(row);
            }
            enc_owned = full;
        }
        let rows = slot_ids.len();
        self.ensure_device_state(client, false)?;
        let t0 = Instant::now();
        let enc_buf =
            client.upload(&Tensor::i32(vec![rows, eff], enc_owned).to_literal()?)?;
        let ids_buf = client.upload(&Tensor::i32(vec![rows], slot_ids.to_vec()).to_literal()?)?;
        self.transfer_seconds += t0.elapsed().as_secs_f64();

        let DecodeSlots { slots: n, mut state } = slots;
        state.push(enc_buf);
        state.push(ids_buf);
        let t1 = Instant::now();
        let outs = {
            let Some(CachedState::Device { params, .. }) = self.state.as_ref() else {
                bail!("device state missing after ensure_device_state");
            };
            let shared: Vec<&xla::PjRtBuffer> = params.iter().collect();
            exe.run_buffers_donating(&shared, state)?
        };
        self.exec_seconds += t1.elapsed().as_secs_f64();
        if outs.len() != self.artifact.decode_state.len() {
            bail!(
                "prefill returned {} outputs, expected {} decode_state slots",
                outs.len(),
                self.artifact.decode_state.len()
            );
        }
        Ok(DecodeSlots { slots: n, state: outs })
    }

    /// Advance every slot with `live[s] == true` by one token: one
    /// fused execute over the whole slot geometry, state donated and
    /// replaced, only the (S,) token row downloaded to host.
    pub fn decode_token(
        &mut self,
        client: &Client,
        slots: DecodeSlots,
        live: &[bool],
    ) -> Result<(DecodeSlots, Vec<i32>)> {
        if self.mode != CacheMode::Device {
            bail!("split decode requires CacheMode::Device (serving default)");
        }
        if live.len() != slots.slots {
            bail!("live mask len {} != slot count {}", live.len(), slots.slots);
        }
        if self.decode_token.is_none() {
            self.decode_token = Some(self.compile(client, "decode_token")?);
        }
        let exe = Rc::clone(self.decode_token.as_ref().unwrap());
        self.ensure_device_state(client, false)?;
        let t0 = Instant::now();
        let mask: Vec<i32> = live.iter().map(|&l| l as i32).collect();
        let mask_buf = client.upload(&Tensor::i32(vec![live.len()], mask).to_literal()?)?;
        self.transfer_seconds += t0.elapsed().as_secs_f64();

        let DecodeSlots { slots: n, mut state } = slots;
        state.push(mask_buf);
        let t1 = Instant::now();
        let mut outs = {
            let Some(CachedState::Device { params, .. }) = self.state.as_ref() else {
                bail!("device state missing after ensure_device_state");
            };
            let shared: Vec<&xla::PjRtBuffer> = params.iter().collect();
            exe.run_buffers_donating(&shared, state)?
        };
        self.exec_seconds += t1.elapsed().as_secs_f64();
        let want = self.artifact.decode_state.len() + 1;
        if outs.len() != want {
            bail!("decode_token returned {} outputs, expected {want}", outs.len());
        }
        let tokens_buf = outs.pop().expect("token output");
        let t2 = Instant::now();
        let tokens = Tensor::from_literal(&tokens_buf.to_literal_sync()?)?.as_i32()?.to_vec();
        self.transfer_seconds += t2.elapsed().as_secs_f64();
        if tokens.len() != n {
            bail!("decode_token emitted {} tokens for {n} slots", tokens.len());
        }
        Ok((DecodeSlots { slots: n, state: outs }, tokens))
    }

    // ----- §L8: speculative draft/verify serving path -----

    /// True when the artifact ships the fused speculative verify
    /// executable for draft length `gamma` (§L8 contract in the module
    /// header).
    pub fn has_verify(&self, gamma: usize) -> bool {
        gamma >= 1 && self.artifact.has(&format!("verify@{gamma}"))
    }

    /// One fused speculative verify step (§L8): score `gamma` drafted
    /// tokens per live slot in a single full-model execute, advance the
    /// decode state by the accepted prefix + 1 correction token, and
    /// return per-slot `(accept_len, correction)` rows. `drafted` is
    /// (S, gamma) row-major; dead rows' values are ignored by the HLO.
    pub fn verify(
        &mut self,
        client: &Client,
        slots: DecodeSlots,
        drafted: &[i32],
        live: &[bool],
        gamma: usize,
    ) -> Result<(DecodeSlots, Vec<i32>, Vec<i32>)> {
        if self.mode != CacheMode::Device {
            bail!("split decode requires CacheMode::Device (serving default)");
        }
        if live.len() != slots.slots {
            bail!("live mask len {} != slot count {}", live.len(), slots.slots);
        }
        if drafted.len() != slots.slots * gamma {
            bail!(
                "drafted len {} != {} slots x gamma {gamma}",
                drafted.len(),
                slots.slots
            );
        }
        let exe = match &self.verify_exe {
            Some((g, exe)) if *g == gamma => Rc::clone(exe),
            _ => {
                let exe = self.compile(client, &format!("verify@{gamma}"))?;
                self.verify_exe = Some((gamma, Rc::clone(&exe)));
                exe
            }
        };
        self.ensure_device_state(client, false)?;
        let t0 = Instant::now();
        let drafted_buf = client
            .upload(&Tensor::i32(vec![slots.slots, gamma], drafted.to_vec()).to_literal()?)?;
        let mask: Vec<i32> = live.iter().map(|&l| l as i32).collect();
        let mask_buf = client.upload(&Tensor::i32(vec![live.len()], mask).to_literal()?)?;
        self.transfer_seconds += t0.elapsed().as_secs_f64();

        let DecodeSlots { slots: n, mut state } = slots;
        state.push(drafted_buf);
        state.push(mask_buf);
        let t1 = Instant::now();
        let mut outs = {
            let Some(CachedState::Device { params, .. }) = self.state.as_ref() else {
                bail!("device state missing after ensure_device_state");
            };
            let shared: Vec<&xla::PjRtBuffer> = params.iter().collect();
            exe.run_buffers_donating(&shared, state)?
        };
        self.exec_seconds += t1.elapsed().as_secs_f64();
        let want = self.artifact.decode_state.len() + 2;
        if outs.len() != want {
            bail!("verify@{gamma} returned {} outputs, expected {want}", outs.len());
        }
        let corr_buf = outs.pop().expect("correction output");
        let accept_buf = outs.pop().expect("accept_len output");
        let t2 = Instant::now();
        let accept =
            Tensor::from_literal(&accept_buf.to_literal_sync()?)?.as_i32()?.to_vec();
        let correction =
            Tensor::from_literal(&corr_buf.to_literal_sync()?)?.as_i32()?.to_vec();
        self.transfer_seconds += t2.elapsed().as_secs_f64();
        if accept.len() != n || correction.len() != n {
            bail!(
                "verify@{gamma} emitted {}/{} rows for {n} slots",
                accept.len(),
                correction.len()
            );
        }
        Ok((DecodeSlots { slots: n, state: outs }, accept, correction))
    }

    /// Roll a DRAFT session's slot state to the accepted prefix + the
    /// correction token after a verify (§L8 `draft_accept` contract) —
    /// the draft advanced γ speculative positions while drafting and
    /// must re-sync to what the full model actually accepted.
    pub fn spec_accept(
        &mut self,
        client: &Client,
        slots: DecodeSlots,
        accept_len: &[i32],
        correction: &[i32],
        live: &[bool],
    ) -> Result<DecodeSlots> {
        if self.mode != CacheMode::Device {
            bail!("split decode requires CacheMode::Device (serving default)");
        }
        if accept_len.len() != slots.slots
            || correction.len() != slots.slots
            || live.len() != slots.slots
        {
            bail!(
                "spec_accept row counts {}/{}/{} != slot count {}",
                accept_len.len(),
                correction.len(),
                live.len(),
                slots.slots
            );
        }
        if self.spec_accept_exe.is_none() {
            self.spec_accept_exe = Some(self.compile(client, "draft_accept")?);
        }
        let exe = Rc::clone(self.spec_accept_exe.as_ref().unwrap());
        self.ensure_device_state(client, false)?;
        let t0 = Instant::now();
        let n = slots.slots;
        let accept_buf =
            client.upload(&Tensor::i32(vec![n], accept_len.to_vec()).to_literal()?)?;
        let corr_buf =
            client.upload(&Tensor::i32(vec![n], correction.to_vec()).to_literal()?)?;
        let mask: Vec<i32> = live.iter().map(|&l| l as i32).collect();
        let mask_buf = client.upload(&Tensor::i32(vec![n], mask).to_literal()?)?;
        self.transfer_seconds += t0.elapsed().as_secs_f64();

        let DecodeSlots { slots: n, mut state } = slots;
        state.push(accept_buf);
        state.push(corr_buf);
        state.push(mask_buf);
        let t1 = Instant::now();
        let outs = {
            let Some(CachedState::Device { params, .. }) = self.state.as_ref() else {
                bail!("device state missing after ensure_device_state");
            };
            let shared: Vec<&xla::PjRtBuffer> = params.iter().collect();
            exe.run_buffers_donating(&shared, state)?
        };
        self.exec_seconds += t1.elapsed().as_secs_f64();
        if outs.len() != self.artifact.decode_state.len() {
            bail!(
                "draft_accept returned {} outputs, expected {} decode_state slots",
                outs.len(),
                self.artifact.decode_state.len()
            );
        }
        Ok(DecodeSlots { slots: n, state: outs })
    }

    // ----- §L9: paged decode-state serving path -----

    /// True when the artifact ships the paged split-decode contract
    /// (module header §L9): a `paged` meta.json entry, a
    /// `decode_token_paged` HLO, a full-length paged prefill entry
    /// point, and the `decode_state` specs the pool is allocated from.
    pub fn has_paged_decode(&self) -> bool {
        if self.artifact.paged.is_none()
            || !self.artifact.has("decode_token_paged")
            || self.artifact.decode_state.is_empty()
        {
            return false;
        }
        self.artifact.has("prefill_paged")
            || self
                .artifact
                .has(&format!("prefill_paged@{}", self.artifact.config.enc_len))
    }

    /// The artifact's KV page size, when it ships the paged contract.
    pub fn page_size(&self) -> Option<usize> {
        self.artifact.paged.as_ref().map(|p| p.page_size)
    }

    /// Worst-case logical pages of one request — the page-table width
    /// of every paged entry point: `ceil((enc_len + dec_len) /
    /// page_size)`.
    pub fn max_pages(&self) -> Result<usize> {
        let p = self.artifact.paged.as_ref().with_context(|| {
            format!("artifact {} ships no paged contract", self.artifact.name)
        })?;
        let cfg = &self.artifact.config;
        Ok(crate::runtime::pages::pages_for(cfg.enc_len + cfg.dec_len, p.page_size))
    }

    /// The sequence length a `prefill_paged(bucket)` call actually
    /// executes at (the paged twin of `effective_prefill_bucket`).
    pub fn effective_paged_prefill_bucket(&self, bucket: usize) -> usize {
        let enc_len = self.artifact.config.enc_len;
        if bucket < enc_len && self.artifact.has(&format!("prefill_paged@{bucket}")) {
            bucket
        } else {
            enc_len
        }
    }

    /// Allocate the device-resident page pool: one zeroed buffer per
    /// `decode_state` spec with a leading `pool_pages` dimension
    /// (physical pages, not slots — which pages belong to which slot
    /// is the page table's business). Same residency/donation
    /// lifecycle as `init_decode_slots`.
    pub fn init_paged_slots(&mut self, client: &Client, pool_pages: usize) -> Result<DecodeSlots> {
        if !self.has_paged_decode() {
            bail!(
                "artifact {} ships no paged decode HLO (prefill_paged/decode_token_paged + paged meta)",
                self.artifact.name
            );
        }
        let t0 = Instant::now();
        let mut state = Vec::with_capacity(self.artifact.decode_state.len());
        for spec in &self.artifact.decode_state {
            let mut shape = vec![pool_pages];
            shape.extend_from_slice(&spec.shape);
            let n: usize = shape.iter().product();
            let zeros = match spec.dtype {
                crate::runtime::tensor::DType::F32 => Tensor::zeros_f32(shape),
                crate::runtime::tensor::DType::I32 => Tensor::i32(shape, vec![0; n]),
                crate::runtime::tensor::DType::U32 => Tensor::u32(shape, vec![0; n]),
            };
            state.push(client.upload(&zeros.to_literal()?)?);
        }
        self.transfer_seconds += t0.elapsed().as_secs_f64();
        Ok(DecodeSlots { slots: pool_pages, state })
    }

    /// Same LRU policy as `prefill_exe`, for the
    /// `prefill_paged@<bucket>` family.
    fn prefill_paged_exe(&mut self, client: &Client, bucket: usize) -> Result<Rc<Executable>> {
        if let Some(exe) = self.prefill_paged_buckets.get(bucket) {
            return Ok(Rc::clone(exe));
        }
        let exe = self.compile(client, &format!("prefill_paged@{bucket}"))?;
        for (evicted, _) in self.prefill_paged_buckets.insert(bucket, Rc::clone(&exe)) {
            let kind = self.shard_kind(&format!("prefill_paged@{evicted}"));
            client.evict(&format!("{}:{}", self.artifact.name, kind));
        }
        Ok(exe)
    }

    fn compile_prefill_paged_full(&mut self, client: &Client) -> Result<Rc<Executable>> {
        if self.artifact.has("prefill_paged") {
            return self.compile(client, "prefill_paged");
        }
        let at_full = format!("prefill_paged@{}", self.artifact.config.enc_len);
        self.compile(client, &at_full)
    }

    /// Paged prefill (§L9): like `prefill`, plus the (P, max_pages)
    /// row-major `page_table` operand mapping each prompt row's logical
    /// pages to pool rows (-1 = unmapped). Rows whose leading pages
    /// were satisfied by the prefix cache arrive with those entries
    /// already mapped; the HLO skips recomputing them.
    pub fn prefill_paged(
        &mut self,
        client: &Client,
        slots: DecodeSlots,
        enc_tokens: &[i32],
        bucket: usize,
        slot_ids: &[i32],
        page_table: &[i32],
    ) -> Result<DecodeSlots> {
        if self.mode != CacheMode::Device {
            bail!("split decode requires CacheMode::Device (serving default)");
        }
        let enc_len = self.artifact.config.enc_len;
        if bucket > enc_len {
            bail!("prefill_paged bucket {bucket} exceeds enc_len {enc_len}");
        }
        if enc_tokens.len() != slot_ids.len() * bucket {
            bail!(
                "prefill_paged batch size {} != {}x{bucket}",
                enc_tokens.len(),
                slot_ids.len()
            );
        }
        let max_pages = self.max_pages()?;
        if page_table.len() != slot_ids.len() * max_pages {
            bail!(
                "prefill_paged page table len {} != {}x{max_pages}",
                page_table.len(),
                slot_ids.len()
            );
        }
        let eff = self.effective_paged_prefill_bucket(bucket);
        let (exe, enc_owned);
        if eff == bucket && bucket < enc_len {
            exe = self.prefill_paged_exe(client, bucket)?;
            enc_owned = enc_tokens.to_vec();
        } else {
            exe = self.compile_prefill_paged_full(client)?;
            let rows = slot_ids.len();
            let mut full = vec![0i32; rows * enc_len];
            for (i, row) in enc_tokens.chunks(bucket).enumerate() {
                full[i * enc_len..i * enc_len + bucket].copy_from_slice(row);
            }
            enc_owned = full;
        }
        let rows = slot_ids.len();
        self.ensure_device_state(client, false)?;
        let t0 = Instant::now();
        let enc_buf =
            client.upload(&Tensor::i32(vec![rows, eff], enc_owned).to_literal()?)?;
        let ids_buf = client.upload(&Tensor::i32(vec![rows], slot_ids.to_vec()).to_literal()?)?;
        let table_buf = client
            .upload(&Tensor::i32(vec![rows, max_pages], page_table.to_vec()).to_literal()?)?;
        self.transfer_seconds += t0.elapsed().as_secs_f64();

        let DecodeSlots { slots: n, mut state } = slots;
        state.push(enc_buf);
        state.push(ids_buf);
        state.push(table_buf);
        let t1 = Instant::now();
        let outs = {
            let Some(CachedState::Device { params, .. }) = self.state.as_ref() else {
                bail!("device state missing after ensure_device_state");
            };
            let shared: Vec<&xla::PjRtBuffer> = params.iter().collect();
            exe.run_buffers_donating(&shared, state)?
        };
        self.exec_seconds += t1.elapsed().as_secs_f64();
        if outs.len() != self.artifact.decode_state.len() {
            bail!(
                "prefill_paged returned {} outputs, expected {} decode_state slots",
                outs.len(),
                self.artifact.decode_state.len()
            );
        }
        Ok(DecodeSlots { slots: n, state: outs })
    }

    /// Paged per-token decode (§L9): like `decode_token`, plus the
    /// (S, max_pages) page-table operand resolving each slot's logical
    /// pages to pool rows.
    pub fn decode_token_paged(
        &mut self,
        client: &Client,
        slots: DecodeSlots,
        live: &[bool],
        page_table: &[i32],
    ) -> Result<(DecodeSlots, Vec<i32>)> {
        if self.mode != CacheMode::Device {
            bail!("split decode requires CacheMode::Device (serving default)");
        }
        let max_pages = self.max_pages()?;
        if page_table.len() != live.len() * max_pages {
            bail!(
                "decode_token_paged page table len {} != {}x{max_pages}",
                page_table.len(),
                live.len()
            );
        }
        if self.decode_token_paged.is_none() {
            self.decode_token_paged = Some(self.compile(client, "decode_token_paged")?);
        }
        let exe = Rc::clone(self.decode_token_paged.as_ref().unwrap());
        self.ensure_device_state(client, false)?;
        let t0 = Instant::now();
        let n_slots = live.len();
        let mask: Vec<i32> = live.iter().map(|&l| l as i32).collect();
        let mask_buf = client.upload(&Tensor::i32(vec![n_slots], mask).to_literal()?)?;
        let table_buf = client
            .upload(&Tensor::i32(vec![n_slots, max_pages], page_table.to_vec()).to_literal()?)?;
        self.transfer_seconds += t0.elapsed().as_secs_f64();

        let DecodeSlots { slots: n, mut state } = slots;
        state.push(mask_buf);
        state.push(table_buf);
        let t1 = Instant::now();
        let mut outs = {
            let Some(CachedState::Device { params, .. }) = self.state.as_ref() else {
                bail!("device state missing after ensure_device_state");
            };
            let shared: Vec<&xla::PjRtBuffer> = params.iter().collect();
            exe.run_buffers_donating(&shared, state)?
        };
        self.exec_seconds += t1.elapsed().as_secs_f64();
        let want = self.artifact.decode_state.len() + 1;
        if outs.len() != want {
            bail!("decode_token_paged returned {} outputs, expected {want}", outs.len());
        }
        let tokens_buf = outs.pop().expect("token output");
        let t2 = Instant::now();
        let tokens = Tensor::from_literal(&tokens_buf.to_literal_sync()?)?.as_i32()?.to_vec();
        self.transfer_seconds += t2.elapsed().as_secs_f64();
        if tokens.len() != n_slots {
            bail!("decode_token_paged emitted {} tokens for {n_slots} slots", tokens.len());
        }
        Ok((DecodeSlots { slots: n, state: outs }, tokens))
    }

    /// True when the artifact ships the paged fused verify for draft
    /// length `gamma` (§L9 twin of `has_verify`).
    pub fn has_verify_paged(&self, gamma: usize) -> bool {
        gamma >= 1 && self.artifact.has(&format!("verify_paged@{gamma}"))
    }

    /// Paged speculative verify (§L9): like `verify`, plus the
    /// (S, max_pages) page-table operand.
    pub fn verify_paged(
        &mut self,
        client: &Client,
        slots: DecodeSlots,
        drafted: &[i32],
        live: &[bool],
        gamma: usize,
        page_table: &[i32],
    ) -> Result<(DecodeSlots, Vec<i32>, Vec<i32>)> {
        if self.mode != CacheMode::Device {
            bail!("split decode requires CacheMode::Device (serving default)");
        }
        let n_slots = live.len();
        if drafted.len() != n_slots * gamma {
            bail!("drafted len {} != {n_slots} slots x gamma {gamma}", drafted.len());
        }
        let max_pages = self.max_pages()?;
        if page_table.len() != n_slots * max_pages {
            bail!(
                "verify_paged page table len {} != {n_slots}x{max_pages}",
                page_table.len()
            );
        }
        let exe = match &self.verify_paged_exe {
            Some((g, exe)) if *g == gamma => Rc::clone(exe),
            _ => {
                let exe = self.compile(client, &format!("verify_paged@{gamma}"))?;
                self.verify_paged_exe = Some((gamma, Rc::clone(&exe)));
                exe
            }
        };
        self.ensure_device_state(client, false)?;
        let t0 = Instant::now();
        let drafted_buf = client
            .upload(&Tensor::i32(vec![n_slots, gamma], drafted.to_vec()).to_literal()?)?;
        let mask: Vec<i32> = live.iter().map(|&l| l as i32).collect();
        let mask_buf = client.upload(&Tensor::i32(vec![n_slots], mask).to_literal()?)?;
        let table_buf = client
            .upload(&Tensor::i32(vec![n_slots, max_pages], page_table.to_vec()).to_literal()?)?;
        self.transfer_seconds += t0.elapsed().as_secs_f64();

        let DecodeSlots { slots: n, mut state } = slots;
        state.push(drafted_buf);
        state.push(mask_buf);
        state.push(table_buf);
        let t1 = Instant::now();
        let mut outs = {
            let Some(CachedState::Device { params, .. }) = self.state.as_ref() else {
                bail!("device state missing after ensure_device_state");
            };
            let shared: Vec<&xla::PjRtBuffer> = params.iter().collect();
            exe.run_buffers_donating(&shared, state)?
        };
        self.exec_seconds += t1.elapsed().as_secs_f64();
        let want = self.artifact.decode_state.len() + 2;
        if outs.len() != want {
            bail!("verify_paged@{gamma} returned {} outputs, expected {want}", outs.len());
        }
        let corr_buf = outs.pop().expect("correction output");
        let accept_buf = outs.pop().expect("accept_len output");
        let t2 = Instant::now();
        let accept =
            Tensor::from_literal(&accept_buf.to_literal_sync()?)?.as_i32()?.to_vec();
        let correction =
            Tensor::from_literal(&corr_buf.to_literal_sync()?)?.as_i32()?.to_vec();
        self.transfer_seconds += t2.elapsed().as_secs_f64();
        if accept.len() != n_slots || correction.len() != n_slots {
            bail!(
                "verify_paged@{gamma} emitted {}/{} rows for {n_slots} slots",
                accept.len(),
                correction.len()
            );
        }
        Ok((DecodeSlots { slots: n, state: outs }, accept, correction))
    }

    /// The full-length prefill entry point: the generic `prefill` HLO
    /// when the artifact ships one, else `prefill@<enc_len>` (an
    /// artifact may name its full-length prefill either way). Cached
    /// process-wide by the client under the artifact key, so no
    /// session-local slot is needed.
    fn compile_prefill_full(&mut self, client: &Client) -> Result<Rc<Executable>> {
        if self.artifact.has("prefill") {
            return self.compile(client, "prefill");
        }
        let at_full = format!("prefill@{}", self.artifact.config.enc_len);
        self.compile(client, &at_full)
    }

    /// Forward-only latency probe: logits for (enc, dec_in).
    pub fn forward_step(&mut self, client: &Client, batch: &Batch) -> Result<()> {
        self.ensure_forward(client)?;
        let exe = Rc::clone(self.forward.as_ref().unwrap());
        let lits = self.batch_literals(batch)?;
        let extra = vec![lits[0].clone(), lits[1].clone()];
        let _ = self.run_with_params(client, exe, extra)?;
        Ok(())
    }
}

fn upload_all(client: &Client, tensors: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
    tensors.iter().map(|t| client.upload(&t.to_literal()?)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::params::tests::toy_artifact;

    /// The device cache's download path must restore the host store
    /// exactly (state-cache coherence without needing a backend: the
    /// vendored xla stub implements upload/download/untuple for real).
    #[test]
    fn device_cache_sync_restores_store() {
        let client = Client::cpu().unwrap();
        let mut s = Session::open_eval(&client, toy_artifact(), 9).unwrap();
        s.set_cache_mode(CacheMode::Device).unwrap();
        let orig: Vec<Vec<f32>> =
            s.store.params.iter().map(|t| t.as_f32().unwrap().to_vec()).collect();

        s.warm_device_cache(&client).unwrap();
        // Clobber the host copy, then pretend the device advanced so
        // sync_store has to restore from the buffers.
        for t in s.store.params.iter_mut() {
            *t = Tensor::zeros_f32(t.shape.clone());
        }
        s.dirty = true;
        s.sync_store().unwrap();
        for (t, o) in s.store.params.iter().zip(orig.iter()) {
            assert_eq!(t.as_f32().unwrap(), &o[..]);
        }
        assert!(!s.dirty, "sync_store must clear dirty");
    }

    /// A clean (non-dirty) cache must never overwrite the store.
    #[test]
    fn clean_cache_does_not_write_back() {
        let client = Client::cpu().unwrap();
        let mut s = Session::open_eval(&client, toy_artifact(), 3).unwrap();
        s.set_cache_mode(CacheMode::Device).unwrap();
        s.warm_device_cache(&client).unwrap();
        let patched = Tensor::f32(vec![2, 2], vec![9.0; 4]);
        s.store.params[0] = patched.clone();
        s.sync_store().unwrap(); // clean cache: no-op
        assert_eq!(s.store.params[0].as_f32().unwrap(), patched.as_f32().unwrap());
    }

    #[test]
    fn invalidate_drops_cache() {
        let client = Client::cpu().unwrap();
        let mut s = Session::open_eval(&client, toy_artifact(), 0).unwrap();
        s.set_cache_mode(CacheMode::Device).unwrap();
        s.warm_device_cache(&client).unwrap();
        assert!(s.state_is_fresh());
        s.invalidate_state();
        assert!(!s.state_is_fresh());
    }

    #[test]
    fn cache_mode_from_env_default_is_device() {
        // Mode precedence is covered without mutating the process env
        // (tests run in parallel threads): the explicit setter is the
        // race-free path, from_env only picks the session default.
        let client = Client::cpu().unwrap();
        let mut s = Session::open_eval(&client, toy_artifact(), 0).unwrap();
        for m in [CacheMode::Off, CacheMode::HostLiteral, CacheMode::Device] {
            s.set_cache_mode(m).unwrap();
            assert_eq!(s.cache_mode(), m);
        }
    }

    /// §L12: the sharded-decode gate requires a declared matching tp
    /// AND every shard's split-decode pair; shard binding then routes
    /// compiles to `/shard<i>` manifest names only where the artifact
    /// ships them, falling back to the whole-model name otherwise.
    #[test]
    fn sharded_decode_gate_and_shard_routing() {
        use crate::runtime::artifact::{DecodeStateSpec, ShardingSpec};
        use crate::runtime::tensor::DType;
        let fake = |k: &str| (k.to_string(), std::path::PathBuf::from("/dev/null"));
        let mut a = toy_artifact();
        // Whole-model split-decode contract (the fallback path).
        a.decode_state.push(DecodeStateSpec {
            name: "kv".into(),
            shape: vec![8, 8],
            dtype: DType::F32,
        });
        a.hlo_files.push(fake("decode_token"));
        a.hlo_files.push(fake("prefill"));
        let s = Session::new(a.clone(), 0);
        assert!(s.has_split_decode());
        assert!(!s.has_sharded_decode(2), "no sharding entry declared");

        a.sharding = Some(ShardingSpec { tp: 2 });
        let s = Session::new(a.clone(), 0);
        assert!(!s.has_sharded_decode(2), "declared but shard executables missing");

        for i in 0..2 {
            a.hlo_files.push(fake(&format!("decode_token/shard{i}")));
            a.hlo_files.push(fake(&format!("prefill/shard{i}")));
        }
        let mut s = Session::new(a, 0);
        assert!(s.has_sharded_decode(2));
        assert!(!s.has_sharded_decode(4), "width mismatch degrades to whole-model");
        assert!(!s.has_sharded_decode(1), "tp<2 is never a group");

        assert_eq!(s.shard_kind("decode_token"), "decode_token", "unbound: plain names");
        s.bind_shard(1);
        assert_eq!(s.shard_kind("decode_token"), "decode_token/shard1");
        assert_eq!(s.shard_kind("prefill"), "prefill/shard1");
        assert_eq!(
            s.shard_kind("train_step"),
            "train_step",
            "no shard variant shipped: whole-model fallback"
        );
    }

    #[test]
    fn bucket_ladder_and_selection() {
        assert_eq!(bucket_lengths(64), vec![8, 16, 32, 64]);
        assert_eq!(bucket_lengths(8), vec![8]);
        assert_eq!(bucket_lengths(4), vec![4]);
        // Non-power-of-two enc_len: ladder tops out at the full length.
        assert_eq!(bucket_lengths(48), vec![8, 16, 32, 48]);

        // Boundary lengths land on the smallest bucket that fits.
        assert_eq!(bucket_for(0, 64), 8);
        assert_eq!(bucket_for(1, 64), 8);
        assert_eq!(bucket_for(8, 64), 8);
        assert_eq!(bucket_for(9, 64), 16);
        assert_eq!(bucket_for(16, 64), 16);
        assert_eq!(bucket_for(17, 64), 32);
        assert_eq!(bucket_for(33, 64), 64);
        assert_eq!(bucket_for(64, 64), 64);
        // Over-length prompts map to the full bucket (truncation is
        // flagged by the packer, not here).
        assert_eq!(bucket_for(65, 64), 64);
        assert_eq!(bucket_for(1000, 64), 64);
        // Gap between the last power of two and a non-pow2 enc_len.
        assert_eq!(bucket_for(33, 48), 48);
        assert_eq!(bucket_for(3, 6), 6);
    }

    #[test]
    fn every_bucket_choice_is_on_the_ladder() {
        for enc_len in [6usize, 8, 13, 32, 48, 100, 128] {
            let ladder = bucket_lengths(enc_len);
            assert_eq!(*ladder.last().unwrap(), enc_len);
            for len in 0..enc_len + 10 {
                let b = bucket_for(len, enc_len);
                assert!(ladder.contains(&b), "len={len} enc={enc_len} b={b}");
                assert!(b >= len.min(enc_len), "bucket must fit the prompt");
            }
        }
    }

    #[test]
    fn effective_bucket_falls_back_without_bucket_hlo() {
        let client = Client::cpu().unwrap();
        let s = Session::open_eval(&client, toy_artifact(), 0).unwrap();
        let enc_len = s.artifact.config.enc_len;
        // toy artifact has no decode_step@N HLOs: everything below the
        // full length falls back to enc_len.
        for b in bucket_lengths(enc_len) {
            assert_eq!(s.effective_bucket(b), enc_len, "bucket {b}");
        }
        assert_eq!(s.effective_bucket(4), enc_len, "sub-ladder bucket falls back");
        assert_eq!(s.effective_bucket(enc_len + 99), enc_len, "over-length clamps");
        assert_eq!(s.bucket_cache_len(), 0);
    }

    #[test]
    fn bucket_lru_prefers_evicting_least_recently_used() {
        let mut lru: BucketLru<&str> = BucketLru::new(2);
        assert!(lru.insert(8, "a").is_empty());
        assert!(lru.insert(16, "b").is_empty());
        // Touch 8: 16 becomes least-recently-used.
        assert_eq!(lru.get(8), Some(&"a"));
        let evicted = lru.insert(32, "c");
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].0, 16, "LRU (not FIFO) order");
        assert_eq!(lru.keys(), vec![8, 32]);
        assert_eq!(lru.get(99), None);
        assert!(BucketLru::<u8>::new(0).cap() >= 1, "zero cap clamps to 1");
    }

    /// The `bucket_exe` contract: under interleaved bucket access the
    /// cap holds, and every inserted entry is either still cached or
    /// was handed back by `insert` exactly once (so `Client::evict`
    /// runs exactly once per evicted executable).
    #[test]
    fn bucket_lru_interleaved_cap_and_exactly_once_eviction() {
        use std::collections::BTreeMap;
        let mut lru: BucketLru<usize> = BucketLru::new(3);
        let mut inserts: BTreeMap<usize, usize> = BTreeMap::new();
        let mut evictions: BTreeMap<usize, usize> = BTreeMap::new();
        let pattern = [8usize, 16, 32, 8, 64, 16, 128, 8, 16, 32, 64, 8, 256, 16];
        for (i, &b) in pattern.iter().enumerate() {
            if lru.get(b).is_none() {
                *inserts.entry(b).or_default() += 1;
                for (e, _) in lru.insert(b, i) {
                    assert!(!lru.keys().contains(&e), "evicted key {e} still cached");
                    *evictions.entry(e).or_default() += 1;
                }
            }
            assert!(lru.len() <= lru.cap(), "cap violated: {}", lru.len());
        }
        let cached = lru.keys();
        for (&b, &n) in &inserts {
            let evicted = evictions.get(&b).copied().unwrap_or(0);
            let still_cached = cached.contains(&b) as usize;
            assert_eq!(
                n,
                evicted + still_cached,
                "bucket {b}: {n} inserts vs {evicted} evictions + cached={still_cached}"
            );
        }
        assert!(evictions.values().sum::<usize>() > 0, "pattern must force evictions");
    }

    #[test]
    fn split_decode_detection_and_fallback() {
        let client = Client::cpu().unwrap();
        let mut s = Session::open_eval(&client, toy_artifact(), 0).unwrap();
        // The toy artifact ships no split HLO: detection is false and
        // the slot-state allocator refuses cleanly.
        assert!(!s.has_split_decode());
        assert!(s.init_decode_slots(&client, 4).is_err());
        let enc_len = s.artifact.config.enc_len;
        assert_eq!(
            s.effective_prefill_bucket(8),
            enc_len,
            "no prefill@8 HLO: falls back to the full-length entry point"
        );

        // With the split HLO entries + decode_state advertised,
        // detection flips on and the slot state allocates one zeroed
        // device buffer per spec (host-backed in the stub).
        let mut a = toy_artifact();
        a.hlo_files.push(("prefill".into(), std::path::PathBuf::from("/nonexistent")));
        a.hlo_files.push(("decode_token".into(), std::path::PathBuf::from("/nonexistent")));
        use crate::runtime::artifact::DecodeStateSpec;
        use crate::runtime::tensor::DType;
        a.decode_state = vec![
            DecodeStateSpec { name: "enc_kv".into(), shape: vec![8, 4], dtype: DType::F32 },
            DecodeStateSpec { name: "pos".into(), shape: vec![], dtype: DType::I32 },
        ];
        let mut s = Session::open_eval(&client, a, 0).unwrap();
        assert!(s.has_split_decode());
        let slots = s.init_decode_slots(&client, 3).unwrap();
        assert_eq!(slots.slots, 3);
        assert_eq!(slots.state.len(), 2);
        assert_eq!(slots.state[0].to_literal_sync().unwrap().element_count(), 3 * 8 * 4);
        // Slot dtypes follow the spec: the i32 position slot must not
        // be allocated as f32 (PJRT rejects mismatched arguments).
        let pos = slots.state[1].to_literal_sync().unwrap();
        assert_eq!(pos.to_vec::<i32>().unwrap(), vec![0, 0, 0]);
        // Executing still requires a real backend: prefill fails with
        // an error (missing/uncompilable HLO), never a panic.
        assert!(s.prefill(&client, slots, &[0; 2 * 8], 8, &[0, 1]).is_err());
    }

    /// §L9 detection + fallback: `has_paged_decode` requires the paged
    /// meta entry AND the paged HLO pair, the pool allocator shapes
    /// buffers with a leading pool-pages dimension, and everything
    /// errors cleanly (fallback to monolithic slots) when any piece is
    /// missing.
    #[test]
    fn paged_decode_detection_and_fallback() {
        use crate::runtime::artifact::{DecodeStateSpec, PagedSpec};
        use crate::runtime::tensor::DType;
        let client = Client::cpu().unwrap();
        let s = Session::open_eval(&client, toy_artifact(), 0).unwrap();
        assert!(!s.has_paged_decode(), "toy artifact ships no paged contract");
        assert_eq!(s.page_size(), None);
        assert!(s.max_pages().is_err());

        // Paged meta entry without the paged HLOs: still monolithic.
        let mut a = toy_artifact();
        a.paged = Some(PagedSpec { page_size: 4 });
        a.decode_state = vec![
            DecodeStateSpec { name: "kv".into(), shape: vec![4, 2], dtype: DType::F32 },
            DecodeStateSpec { name: "fill".into(), shape: vec![], dtype: DType::I32 },
        ];
        let s = Session::open_eval(&client, a.clone(), 0).unwrap();
        assert!(!s.has_paged_decode(), "paged meta without paged HLOs");
        assert_eq!(s.page_size(), Some(4));
        // enc_len 8 + dec_len 4 at page size 4 -> 3 logical pages max.
        assert_eq!(s.max_pages().unwrap(), 3);

        // Full contract: detection flips on, the pool allocates with a
        // leading pool-pages dimension (not a slot dimension).
        a.hlo_files.push(("prefill_paged".into(), std::path::PathBuf::from("/nonexistent")));
        a.hlo_files
            .push(("decode_token_paged".into(), std::path::PathBuf::from("/nonexistent")));
        let mut s = Session::open_eval(&client, a, 0).unwrap();
        assert!(s.has_paged_decode());
        assert!(!s.has_verify_paged(4), "no verify_paged HLO shipped");
        let pool = s.init_paged_slots(&client, 6).unwrap();
        assert_eq!(pool.slots, 6, "leading dim is pool pages");
        assert_eq!(pool.state.len(), 2);
        assert_eq!(pool.state[0].to_literal_sync().unwrap().element_count(), 6 * 4 * 2);
        let fill = pool.state[1].to_literal_sync().unwrap();
        assert_eq!(fill.to_vec::<i32>().unwrap(), vec![0; 6], "dtype honored");

        // Shape validation fires before any compile: a wrong-width
        // page table is rejected, and with correct shapes but no real
        // backend the call errors (missing HLO file), never panics.
        let table = vec![-1i32; 2 * 3];
        assert!(s
            .prefill_paged(&client, pool, &[0; 2 * 8], 8, &[0, 1], &table[..4])
            .is_err());
        let pool = s.init_paged_slots(&client, 6).unwrap();
        assert!(s.prefill_paged(&client, pool, &[0; 2 * 8], 8, &[0, 1], &table).is_err());
        let pool = s.init_paged_slots(&client, 6).unwrap();
        assert!(s.decode_token_paged(&client, pool, &[true, true], &table).is_err());

        // The paged contract is independent of the L6 monolithic one:
        // this artifact ships only paged HLOs, so the monolithic slot
        // allocator still refuses (serving picks the path per session).
        assert!(!s.has_split_decode());
        assert!(s.init_decode_slots(&client, 2).is_err());
    }

    /// §L8 detection + error paths: `has_verify` keys on the exact
    /// `verify@<gamma>` HLO entry, shape validation fires before any
    /// compile, and executing without a real backend errors cleanly.
    #[test]
    fn spec_verify_detection_and_error_paths() {
        let client = Client::cpu().unwrap();
        let s = Session::open_eval(&client, toy_artifact(), 0).unwrap();
        assert!(!s.has_verify(4), "no verify HLO shipped");
        assert!(!s.has_verify(0), "gamma 0 is never valid");

        let mut a = toy_artifact();
        a.hlo_files.push(("prefill".into(), std::path::PathBuf::from("/nonexistent")));
        a.hlo_files.push(("decode_token".into(), std::path::PathBuf::from("/nonexistent")));
        a.hlo_files.push(("verify@4".into(), std::path::PathBuf::from("/nonexistent")));
        use crate::runtime::artifact::DecodeStateSpec;
        use crate::runtime::tensor::DType;
        a.decode_state = vec![DecodeStateSpec {
            name: "kv".into(),
            shape: vec![4, 2],
            dtype: DType::F32,
        }];
        let mut s = Session::open_eval(&client, a, 0).unwrap();
        assert!(s.has_verify(4));
        assert!(!s.has_verify(2), "only the shipped gamma verifies");

        // Wrong drafted geometry: rejected before any compile attempt.
        let slots = s.init_decode_slots(&client, 2).unwrap();
        assert!(s.verify(&client, slots, &[0; 3], &[true, true], 4).is_err());
        // Correct shapes but no real backend: error, never a panic.
        let slots = s.init_decode_slots(&client, 2).unwrap();
        assert!(s.verify(&client, slots, &[0; 8], &[true, true], 4).is_err());
        let slots = s.init_decode_slots(&client, 2).unwrap();
        assert!(s
            .spec_accept(&client, slots, &[1, 0], &[5, 5], &[true, true])
            .is_err());
    }

    #[test]
    fn warm_cache_is_noop_off_device_mode() {
        let client = Client::cpu().unwrap();
        let mut s = Session::open_eval(&client, toy_artifact(), 0).unwrap();
        s.set_cache_mode(CacheMode::Off).unwrap();
        s.warm_device_cache(&client).unwrap();
        assert!(s.state.is_none());
        assert_eq!(s.transfer_seconds, 0.0);
    }
}
