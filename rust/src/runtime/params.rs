//! Parameter store: host-side model state initialized from the
//! artifact's init specs, plus binary checkpointing.
//!
//! Initialization is deterministic (SplitMix64 per-parameter streams),
//! so a (config, seed) pair always yields the same model — across runs
//! and across experiment harnesses.

use crate::runtime::artifact::{Artifact, InitSpec};
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Host-resident parameters + optimizer state, in meta.json order.
/// `Clone` is the in-memory weight-fork primitive (finetune fan-out
/// clones a pretrained store per task without a checkpoint round-trip).
#[derive(Clone)]
pub struct ParamStore {
    pub names: Vec<String>,
    pub params: Vec<Tensor>,
    pub opt_names: Vec<String>,
    pub opt: Vec<Tensor>,
    pub step: u64,
}

impl ParamStore {
    /// Initialize from the artifact's init specs.
    pub fn init(artifact: &Artifact, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed ^ 0xA17A_B001);
        let mut params = Vec::with_capacity(artifact.params.len());
        let mut names = Vec::with_capacity(artifact.params.len());
        for spec in &artifact.params {
            let n: usize = spec.shape.iter().product();
            let mut stream = rng.fork(hash_name(&spec.name));
            let data: Vec<f32> = match &spec.init {
                InitSpec::Zeros => vec![0.0; n],
                InitSpec::Ones => vec![1.0; n],
                InitSpec::Eye { scale } => {
                    let dim = spec.shape[0];
                    let mut v = vec![0.0f32; n];
                    for i in 0..dim {
                        v[i * dim + i] = *scale as f32;
                    }
                    v
                }
                InitSpec::Normal { scale } => (0..n)
                    .map(|_| (stream.next_normal() * scale) as f32)
                    .collect(),
            };
            params.push(Tensor::f32(spec.shape.clone(), data));
            names.push(spec.name.clone());
        }
        let mut opt = Vec::with_capacity(artifact.opt_state.len());
        let mut opt_names = Vec::with_capacity(artifact.opt_state.len());
        for slot in &artifact.opt_state {
            opt.push(Tensor::zeros_f32(slot.shape.clone()));
            opt_names.push(slot.name.clone());
        }
        ParamStore { names, params, opt_names, opt, step: 0 }
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(|t| t.len()).sum()
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.names.iter().position(|n| n == name).map(|i| &self.params[i])
    }

    /// RMS of one parameter (diagnostics).
    pub fn rms(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|t| t.as_f32().ok()).map(|v| {
            (v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / v.len() as f64).sqrt()
        })
    }

    // ------------------------------------------------------------------
    // Checkpointing: minimal length-prefixed binary format (magic,
    // step, then name/shape/data records for params and opt state).
    // ------------------------------------------------------------------

    const MAGIC: &'static [u8; 8] = b"ALTUPCK1";

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        f.write_all(Self::MAGIC)?;
        f.write_all(&self.step.to_le_bytes())?;
        for (section, names, tensors) in [
            (0u32, &self.names, &self.params),
            (1u32, &self.opt_names, &self.opt),
        ] {
            f.write_all(&section.to_le_bytes())?;
            f.write_all(&(names.len() as u32).to_le_bytes())?;
            for (name, t) in names.iter().zip(tensors.iter()) {
                let nb = name.as_bytes();
                f.write_all(&(nb.len() as u32).to_le_bytes())?;
                f.write_all(nb)?;
                f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
                for &d in &t.shape {
                    f.write_all(&(d as u64).to_le_bytes())?;
                }
                let data = t.as_f32()?;
                // SAFETY: f32 slice to bytes, little-endian hosts only.
                let bytes = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                f.write_all(bytes)?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>, artifact: &Artifact) -> Result<ParamStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("bad checkpoint magic");
        }
        let mut step_bytes = [0u8; 8];
        f.read_exact(&mut step_bytes)?;
        let step = u64::from_le_bytes(step_bytes);

        let mut store = ParamStore::init(artifact, 0);
        store.step = step;
        for expected_section in 0u32..2 {
            let mut b4 = [0u8; 4];
            f.read_exact(&mut b4)?;
            if u32::from_le_bytes(b4) != expected_section {
                bail!("checkpoint section mismatch");
            }
            f.read_exact(&mut b4)?;
            let count = u32::from_le_bytes(b4) as usize;
            for _ in 0..count {
                f.read_exact(&mut b4)?;
                let name_len = u32::from_le_bytes(b4) as usize;
                let mut nb = vec![0u8; name_len];
                f.read_exact(&mut nb)?;
                let name = String::from_utf8(nb)?;
                f.read_exact(&mut b4)?;
                let rank = u32::from_le_bytes(b4) as usize;
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    let mut b8 = [0u8; 8];
                    f.read_exact(&mut b8)?;
                    shape.push(u64::from_le_bytes(b8) as usize);
                }
                let n: usize = shape.iter().product();
                let mut bytes = vec![0u8; n * 4];
                f.read_exact(&mut bytes)?;
                let data: Vec<f32> = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                let (names, tensors) = if expected_section == 0 {
                    (&store.names, &mut store.params)
                } else {
                    (&store.opt_names, &mut store.opt)
                };
                let idx = names
                    .iter()
                    .position(|x| *x == name)
                    .with_context(|| format!("checkpoint tensor {name} not in artifact"))?;
                if tensors[idx].shape != shape {
                    bail!(
                        "checkpoint shape mismatch for {name}: {:?} vs {:?}",
                        shape,
                        tensors[idx].shape
                    );
                }
                tensors[idx] = Tensor::f32(shape, data);
            }
        }
        Ok(store)
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::runtime::artifact::{BatchInputSpec, OptSlotSpec, ParamSpec};
    use crate::runtime::tensor::DType;
    use crate::config::{ModelConfig, Variant};

    pub(crate) fn toy_artifact() -> Artifact {
        Artifact {
            name: "toy".into(),
            dir: std::path::PathBuf::from("/tmp"),
            config: ModelConfig {
                name: "toy".into(),
                d_model: 8, d_ff: 16, num_heads: 2, d_head: 4,
                enc_layers: 1, dec_layers: 1, vocab_size: 32,
                rel_pos_buckets: 8, enc_len: 8, dec_len: 4, batch_size: 2,
                variant: Variant::AltUp, k: 2, seq_stride: 4,
                moe: false, moe_experts: 4, moe_hidden: 4, dropout: 0.0,
            },
            raw_config: crate::util::json::Json::Null,
            params: vec![
                ParamSpec { name: "a/p".into(), shape: vec![2, 2], dtype: DType::F32, init: InitSpec::Eye { scale: 1.0 } },
                ParamSpec { name: "a/w".into(), shape: vec![8, 16], dtype: DType::F32, init: InitSpec::Normal { scale: 0.35 } },
                ParamSpec { name: "b/s".into(), shape: vec![8], dtype: DType::F32, init: InitSpec::Ones },
            ],
            opt_state: vec![
                OptSlotSpec { name: "a/p@v".into(), shape: vec![2, 2] },
                OptSlotSpec { name: "a/w@vr".into(), shape: vec![8] },
                OptSlotSpec { name: "a/w@vc".into(), shape: vec![16] },
                OptSlotSpec { name: "b/s@v".into(), shape: vec![8] },
            ],
            decode_state: vec![],
            draft: None,
            paged: None,
            sharding: None,
            batch_inputs: vec![BatchInputSpec { name: "enc".into(), shape: vec![2, 8] }],
            hlo_files: vec![],
            version: "unversioned".into(),
            fingerprint: 0,
            param_count_total: 4 + 128 + 8,
            param_count_embedding: 0,
            flops_per_token: 1.0,
        }
    }

    #[test]
    fn deterministic_init() {
        let a = toy_artifact();
        let s1 = ParamStore::init(&a, 7);
        let s2 = ParamStore::init(&a, 7);
        assert_eq!(s1.params[1].as_f32().unwrap(), s2.params[1].as_f32().unwrap());
        let s3 = ParamStore::init(&a, 8);
        assert_ne!(s1.params[1].as_f32().unwrap(), s3.params[1].as_f32().unwrap());
    }

    #[test]
    fn init_specs_honored() {
        let a = toy_artifact();
        let s = ParamStore::init(&a, 0);
        assert_eq!(s.params[0].as_f32().unwrap(), &[1.0, 0.0, 0.0, 1.0]);
        assert!(s.params[2].as_f32().unwrap().iter().all(|&x| x == 1.0));
        let w = s.params[1].as_f32().unwrap();
        let rms = (w.iter().map(|&x| x * x).sum::<f32>() / w.len() as f32).sqrt();
        assert!((rms - 0.35).abs() < 0.08, "rms={rms}");
        assert!(s.opt.iter().all(|t| t.as_f32().unwrap().iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn checkpoint_roundtrip() {
        let a = toy_artifact();
        let mut s = ParamStore::init(&a, 3);
        s.step = 42;
        let path = std::env::temp_dir().join(format!("altup-ckpt-{}", std::process::id()));
        s.save(&path).unwrap();
        let r = ParamStore::load(&path, &a).unwrap();
        assert_eq!(r.step, 42);
        for (t1, t2) in s.params.iter().zip(r.params.iter()) {
            assert_eq!(t1.as_f32().unwrap(), t2.as_f32().unwrap());
        }
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn clone_forks_weights_in_memory() {
        let a = toy_artifact();
        let base = ParamStore::init(&a, 5);
        let mut fork = base.clone();
        assert_eq!(fork.step, base.step);
        let zeroed = Tensor::zeros_f32(fork.params[1].shape.clone());
        fork.params[1] = zeroed;
        // Deep clone: mutating the fork must not touch the base.
        assert!(base.params[1].as_f32().unwrap().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn num_params() {
        let s = ParamStore::init(&toy_artifact(), 0);
        assert_eq!(s.num_params(), 4 + 128 + 8);
    }
}
