//! PJRT client wrapper + executable cache.
//!
//! One process-wide CPU client; compiled executables are cached per
//! (artifact, kind) so experiment harnesses can hop between variants
//! without recompiling.

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// Wrapper around the PJRT CPU client (xla crate).
pub struct Client {
    inner: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

/// A compiled HLO executable plus compile-time metadata.
pub struct Executable {
    pub exe: xla::PjRtLoadedExecutable,
    pub key: String,
    pub compile_seconds: f64,
}

impl Client {
    pub fn cpu() -> Result<Client> {
        let inner = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Client { inner, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }

    /// Load HLO text from `path`, compile, cache under `key`.
    pub fn compile_hlo(&self, key: &str, path: &Path) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(Rc::clone(e));
        }
        let t0 = Instant::now();
        let path_str = path.to_str().context("non-utf8 path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path_str}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        let e = Rc::new(Executable {
            exe,
            key: key.to_string(),
            compile_seconds: t0.elapsed().as_secs_f64(),
        });
        self.cache.borrow_mut().insert(key.to_string(), Rc::clone(&e));
        Ok(e)
    }

    pub fn cached_keys(&self) -> Vec<String> {
        self.cache.borrow().keys().cloned().collect()
    }

    /// Drop one cached executable (the session's bucketed-decode LRU
    /// calls this on eviction so the memory is actually released; the
    /// executable frees once the last `Rc` clone drops).
    pub fn evict(&self, key: &str) {
        self.cache.borrow_mut().remove(key);
    }

    /// Copy a host literal into a device buffer (§Perf L4: the upload
    /// half of the device-resident state cache — see EXPERIMENTS.md).
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.inner.buffer_from_host_literal(None, lit)?)
    }
}

impl Executable {
    /// Execute with literal inputs (owned or borrowed); returns the
    /// decomposed output tuple.
    ///
    /// All our artifacts are lowered with `return_tuple=True`, so the
    /// root is a single tuple buffer; we pull it to host and split.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let outs = self.exe.execute::<L>(inputs)?;
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with device-resident buffers (no input host copies),
    /// but still sync the whole output tuple to host. Prefer
    /// `run_buffers` on hot paths — this remains for callers that need
    /// every output on host anyway.
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let outs = self.exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with device-resident buffers and keep the outputs
    /// device-resident too (§Perf L4): the root tuple is decomposed
    /// into per-element `PjRtBuffer`s without a host sync, so callers
    /// pull only what they actually need (e.g. the 3 scalar metrics of
    /// a train step) via `to_literal_sync`.
    pub fn run_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut outs = self.exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        let elems = outs.swap_remove(0);
        if elems.len() == 1 {
            // return_tuple=True artifacts: one tuple-rooted buffer.
            Ok(elems[0].untuple()?)
        } else {
            // Backend already untupled (PJRT untuple_result).
            Ok(elems)
        }
    }

    /// Execute with a borrowed prefix (`shared`, e.g. the device-
    /// resident parameter cache) followed by consumed inputs
    /// (`donated`), in that argument order. The donated buffers are the
    /// step's state operands (KV caches, per-token scratch): the HLO is
    /// lowered with input/output aliasing on them, so a real PJRT
    /// backend reuses their device memory for the matching outputs
    /// instead of allocating a second copy per token — the iteration-
    /// level decode loop would otherwise double its cache footprint
    /// every step. Host-side the contract is enforced by moving the
    /// buffers in: they are dropped (freed) when the call returns and
    /// cannot be reused by the caller.
    pub fn run_buffers_donating(
        &self,
        shared: &[&xla::PjRtBuffer],
        donated: Vec<xla::PjRtBuffer>,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let refs: Vec<&xla::PjRtBuffer> =
            shared.iter().copied().chain(donated.iter()).collect();
        let out = self.run_buffers(&refs);
        drop(refs);
        drop(donated); // aliased memory is owned by the outputs now
        out
    }
}
