//! PJRT runtime: load AOT HLO artifacts, compile once, execute from the
//! coordinator's hot path (DESIGN.md S7-S8). Python never runs here.

pub mod artifact;
pub mod client;
pub mod pages;
pub mod params;
pub mod session;
pub mod tensor;
