//! Artifact loading: `artifacts/<name>/{meta.json, *.hlo.txt}`.
//!
//! `meta.json` is the contract between the python compile path and this
//! runtime: flat parameter order (sorted names), shapes, init specs,
//! opt-state slots, and the train/eval/decode input signatures.

use crate::config::ModelConfig;
use crate::runtime::pages::fnv1a_bytes;
use crate::runtime::tensor::DType;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub init: InitSpec,
}

#[derive(Debug, Clone)]
pub enum InitSpec {
    Normal { scale: f64 },
    Zeros,
    Ones,
    Eye { scale: f64 },
}

#[derive(Debug, Clone)]
pub struct OptSlotSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct BatchInputSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// A decode-state slot for the split-decode serving path: like an opt
/// slot but dtype-carrying — KV caches are f32 while decoder position
/// / last-token slots are i32, and the runtime must allocate each
/// buffer with the dtype the HLO expects.
#[derive(Debug, Clone)]
pub struct DecodeStateSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Optional speculative-decoding draft reference (§L8): a second,
/// cheaper artifact (e.g. a recycled AltUp-lite model per fig5) whose
/// session proposes tokens that this artifact's fused `verify@<gamma>`
/// executable scores in one step. Shipped as an optional `draft`
/// object in meta.json:
///
///   "draft": {"artifact": "micro-altup-lite", "gamma": 4}
///
/// The draft artifact must share the serving geometry (enc_len,
/// dec_len, vocab) and ship its own split-decode HLO pair plus the
/// `draft_accept` rollback entry point (see the `runtime::session`
/// §L8 contract).
#[derive(Debug, Clone)]
pub struct DraftSpec {
    /// Draft artifact name, resolved against the same artifacts root
    /// (`load_named`).
    pub artifact: String,
    /// The draft length γ the main artifact's fused verify HLO was
    /// compiled for. Serving speculates at the requested `--spec-gamma`
    /// when a `verify@<requested>` HLO exists, and falls back to this
    /// compiled γ otherwise (`Engine::effective_spec_gamma`); with
    /// neither verify present, the replica runs plain decode.
    pub gamma: usize,
}

/// Optional paged decode-state contract (§L9): the artifact's decode
/// state is organized as a pool of fixed-size KV pages addressed
/// through per-slot page tables, instead of one monolithic buffer per
/// slot. Shipped as an optional `paged` object in meta.json:
///
///   "paged": {"page_size": 16}
///
/// An artifact declaring this must also ship the page-table-operand
/// entry points (`prefill_paged@<bucket>`, `decode_token_paged`,
/// optionally `verify_paged@<gamma>`) — see the `runtime::session` §L9
/// contract. The `decode_state` slot shapes stay per-request; the
/// runtime allocates them with a leading pool-pages dimension rather
/// than a slot dimension.
#[derive(Debug, Clone)]
pub struct PagedSpec {
    /// Tokens per KV page — the granularity of pool allocation and of
    /// prefix sharing. Must match what the paged HLOs were compiled
    /// for.
    pub page_size: usize,
}

/// Optional tensor-parallel sharding contract (§L12): the artifact
/// additionally ships per-shard executables for a `tp`-way split of
/// the model (head-sharded attention, column/row-split FFN, AltUp
/// predict/correct replicated per shard). Shipped as an optional
/// `sharding` object in meta.json:
///
///   "sharding": {"tp": 2}
///
/// An artifact declaring this must ship, for every shard `i` in
/// `0..tp`, shard-suffixed variants of the split-serving entry points
/// (`prefill@<bucket>/shard<i>`, `decode_token/shard<i>`, and the
/// paged/verify families where present) — see the `runtime::session`
/// §L12 contract. The whole-model executables stay in the manifest;
/// serving falls back to them automatically when the requested group
/// width does not match `tp` or a shard executable is missing.
#[derive(Debug, Clone)]
pub struct ShardingSpec {
    /// Number of shards the per-shard executables were compiled for.
    pub tp: usize,
}

/// Parsed meta.json + paths of the HLO files.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub raw_config: Json,
    pub params: Vec<ParamSpec>,
    pub opt_state: Vec<OptSlotSpec>,
    /// Per-slot decode-state slots (KV caches, decoder position, last
    /// token) for the split `prefill@<bucket>` / `decode_token`
    /// serving path. Shapes are per-request; the runtime prepends the
    /// slot dimension. Optional — absent from artifacts that only ship
    /// the monolithic `decode_step`.
    pub decode_state: Vec<DecodeStateSpec>,
    /// Optional draft-model reference for speculative decoding (§L8).
    /// Absent from artifacts that ship no draft; serving then falls
    /// back to plain per-token decode.
    pub draft: Option<DraftSpec>,
    /// Optional paged decode-state contract (§L9). Absent from
    /// artifacts whose decode state is per-slot monolithic; serving
    /// then falls back to monolithic `DecodeSlots`.
    pub paged: Option<PagedSpec>,
    /// Optional tensor-parallel sharding contract (§L12). Absent from
    /// artifacts that ship only whole-model executables; serving then
    /// runs every fleet unit unsharded.
    pub sharding: Option<ShardingSpec>,
    pub batch_inputs: Vec<BatchInputSpec>,
    pub hlo_files: Vec<(String, PathBuf)>,
    /// Human-readable version label from the optional meta.json
    /// `version` entry (§L11 deployments roll between these);
    /// "unversioned" when the compile path did not stamp one.
    pub version: String,
    /// Load-time identity: FNV-1a of the raw meta.json text. Two
    /// artifact dirs with byte-identical metas (which, when `checksums`
    /// is present, pins the HLO bytes too) share a fingerprint; any
    /// param/shape/HLO-manifest change moves it. Deployment uses this
    /// to tell "same version reloaded" from "new version".
    pub fingerprint: u64,
    pub param_count_total: usize,
    pub param_count_embedding: usize,
    pub flops_per_token: f64,
}

impl Artifact {
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifact> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {} (run `make artifacts`)", meta_path.display()))?;
        let meta = Json::parse(&text).context("parsing meta.json")?;

        let mut params = Vec::new();
        for p in meta.get("params").as_arr().context("meta.params")? {
            let name = p.get("name").as_str().context("param name")?.to_string();
            let shape: Vec<usize> = p
                .get("shape")
                .as_arr()
                .context("param shape")?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            let scale = p.get("scale").as_f64().unwrap_or(1.0);
            let init = match p.get("init").as_str().unwrap_or("normal") {
                "zeros" => InitSpec::Zeros,
                "ones" => InitSpec::Ones,
                "eye" => InitSpec::Eye { scale },
                _ => InitSpec::Normal { scale },
            };
            let dtype = DType::from_str(p.get("dtype").as_str().unwrap_or("f32"))?;
            params.push(ParamSpec { name, shape, dtype, init });
        }
        // Contract: params are sorted by name (positional marshalling).
        for w in params.windows(2) {
            if w[0].name >= w[1].name {
                bail!("meta.json params not sorted: {} >= {}", w[0].name, w[1].name);
            }
        }
        // §L11 hardening: a zero dimension means the shape entry was
        // malformed (non-integer dims parse as 0 above) — catch it
        // here as a typed load error instead of a first-execute panic
        // when the runtime tries to allocate the buffer.
        for p in &params {
            if p.shape.iter().any(|&d| d == 0) {
                bail!("param {} has malformed shape {:?} (zero/non-integer dim)", p.name, p.shape);
            }
        }
        // §L11 hardening: the compile path pins param_count.total ==
        // sum of parameter elements (python/tests/test_aot.py), so a
        // disagreement means the params table and the HLO it was
        // lowered with have drifted apart — a load error, not a shape
        // mismatch at first execute.
        let declared_total = meta.get("param_count").get("total").as_usize().unwrap_or(0);
        let param_elems: usize = params.iter().map(|p| p.shape.iter().product::<usize>()).sum();
        if declared_total > 0 && declared_total != param_elems {
            bail!(
                "meta.json param_count.total = {declared_total} but params table sums to \
                 {param_elems} elements: artifact params/HLO mismatch"
            );
        }

        let mut opt_state = Vec::new();
        for o in meta.get("opt_state").as_arr().context("meta.opt_state")? {
            opt_state.push(OptSlotSpec {
                name: o.get("name").as_str().context("opt name")?.to_string(),
                shape: o
                    .get("shape")
                    .as_arr()
                    .context("opt shape")?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect(),
            });
        }

        let mut decode_state = Vec::new();
        if let Some(slots) = meta.get("decode_state").as_arr() {
            for o in slots {
                decode_state.push(DecodeStateSpec {
                    name: o.get("name").as_str().context("decode_state name")?.to_string(),
                    shape: o
                        .get("shape")
                        .as_arr()
                        .context("decode_state shape")?
                        .iter()
                        .map(|v| v.as_usize().unwrap_or(0))
                        .collect(),
                    dtype: DType::from_str(o.get("dtype").as_str().unwrap_or("f32"))?,
                });
            }
        }

        let draft = match meta.get("draft").get("artifact").as_str() {
            Some(name) => {
                // Absent gamma defaults to 4; a PRESENT but malformed
                // gamma (string, negative, zero) is a hard error — it
                // would otherwise silently change the speculation
                // length the artifact was compiled for.
                let gamma = match meta.get("draft").get("gamma") {
                    Json::Null => 4,
                    g => g
                        .as_usize()
                        .filter(|&v| v >= 1)
                        .context("meta.json draft.gamma must be a positive integer")?,
                };
                Some(DraftSpec { artifact: name.to_string(), gamma })
            }
            None => None,
        };

        let paged = match meta.get("paged") {
            Json::Null => None,
            p => {
                // Absent page_size defaults to 16; a PRESENT but
                // malformed page_size (string, negative, zero) is a
                // hard error — it would silently change the page
                // granularity the paged HLOs were compiled for.
                let page_size = match p.get("page_size") {
                    Json::Null => 16,
                    v => v
                        .as_usize()
                        .filter(|&v| v >= 1)
                        .context("meta.json paged.page_size must be a positive integer")?,
                };
                Some(PagedSpec { page_size })
            }
        };

        let sharding = match meta.get("sharding") {
            Json::Null => None,
            s => {
                // Absent tp defaults to 2; a PRESENT but malformed tp
                // (string, negative, < 2) is a hard error — a group
                // built against the wrong shard count would bind shard
                // executables that do not exist or partition the wrong
                // dimension.
                let tp = match s.get("tp") {
                    Json::Null => 2,
                    v => v
                        .as_usize()
                        .filter(|&v| v >= 2)
                        .context("meta.json sharding.tp must be an integer >= 2")?,
                };
                Some(ShardingSpec { tp })
            }
        };

        let mut batch_inputs = Vec::new();
        for b in meta.get("batch_inputs").as_arr().context("meta.batch_inputs")? {
            batch_inputs.push(BatchInputSpec {
                name: b.get("name").as_str().context("batch name")?.to_string(),
                shape: b
                    .get("shape")
                    .as_arr()
                    .context("batch shape")?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect(),
            });
        }

        let mut hlo_files = Vec::new();
        if let Some(arts) = meta.get("artifacts").as_obj() {
            for (k, v) in arts {
                if let Some(rel) = v.as_str() {
                    hlo_files.push((k.clone(), dir.join(rel)));
                }
            }
        }

        // §L11 hardening: optional per-HLO checksums. Each entry maps
        // an `artifacts` key to the FNV-1a of that file's bytes as a
        // 16-hex-digit string (`fnv1a_bytes`, same constants as the
        // §L9 prefix hashes). When present, a truncated/corrupted/
        // swapped HLO fails HERE with a typed error the deploy gate
        // can surface, instead of panicking a replica at first
        // execute. Files without an entry are not verified.
        if let Some(sums) = meta.get("checksums").as_obj() {
            for (k, v) in sums {
                let want = v
                    .as_str()
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .with_context(|| {
                        format!("meta.json checksums.{k} must be a 16-hex-digit FNV-1a string")
                    })?;
                let path = hlo_files
                    .iter()
                    .find(|(name, _)| name == k)
                    .map(|(_, p)| p.clone())
                    .with_context(|| {
                        format!("meta.json checksums.{k} names no entry in `artifacts`")
                    })?;
                let bytes = std::fs::read(&path).with_context(|| {
                    format!("reading {} to verify checksums.{k}", path.display())
                })?;
                let got = fnv1a_bytes(&bytes);
                if got != want {
                    bail!(
                        "HLO checksum mismatch for '{k}' ({}): expected {want:016x}, file hashes \
                         to {got:016x} — artifact is truncated or corrupt",
                        path.display()
                    );
                }
            }
        }

        let raw_config = meta.get("config").clone();
        let config = ModelConfig::from_json(&raw_config)?;
        Ok(Artifact {
            name: meta.get("name").as_str().unwrap_or("unnamed").to_string(),
            dir,
            config,
            raw_config,
            params,
            opt_state,
            decode_state,
            draft,
            paged,
            sharding,
            batch_inputs,
            hlo_files,
            version: meta.get("version").as_str().unwrap_or("unversioned").to_string(),
            fingerprint: fnv1a_bytes(text.as_bytes()),
            param_count_total: declared_total,
            param_count_embedding: meta
                .get("param_count")
                .get("embedding")
                .as_usize()
                .unwrap_or(0),
            flops_per_token: meta.get("flops_per_token").as_f64().unwrap_or(0.0),
        })
    }

    pub fn hlo_path(&self, kind: &str) -> Result<&Path> {
        self.hlo_files
            .iter()
            .find(|(k, _)| k == kind)
            .map(|(_, p)| p.as_path())
            .with_context(|| format!("artifact {} has no '{kind}' HLO (available: {:?})",
                self.name, self.hlo_files.iter().map(|(k, _)| k).collect::<Vec<_>>()))
    }

    pub fn has(&self, kind: &str) -> bool {
        self.hlo_files.iter().any(|(k, _)| k == kind)
    }

    /// Total number of f32 elements across parameters.
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }
}

/// Locate the artifacts root: $ALTUP_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("ALTUP_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Load an artifact by suite name, e.g. "micro-altup".
pub fn load_named(name: &str) -> Result<Artifact> {
    Artifact::load(artifacts_root().join(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_meta() -> String {
        r#"{
          "name": "t", "artifacts": {"train_step": "train_step.hlo.txt"},
          "config": {"name":"t","d_model":8,"d_ff":16,"num_heads":2,"d_head":4,
            "enc_layers":1,"dec_layers":1,"vocab_size":32,"rel_pos_buckets":8,
            "rel_pos_max_dist":16,"enc_len":8,"dec_len":4,"batch_size":2,
            "variant":"altup","k":2,"seq_stride":4,"seq_first_layer":1,
            "moe":false,"moe_experts":4,"moe_hidden":4,"kernels":"jnp",
            "dropout":0.0,"label_smoothing":0.0,"tie_embeddings":false},
          "params": [
            {"name":"a/w","shape":[8,16],"dtype":"f32","init":"normal","scale":0.35},
            {"name":"b/g","shape":[2],"dtype":"f32","init":"ones","scale":1.0}
          ],
          "opt_state": [
            {"name":"a/w@vr","shape":[8],"dtype":"f32"},
            {"name":"a/w@vc","shape":[16],"dtype":"f32"},
            {"name":"b/g@v","shape":[2],"dtype":"f32"}
          ],
          "decode_state": [
            {"name":"enc_kv","shape":[8,8],"dtype":"f32"},
            {"name":"pos","shape":[],"dtype":"i32"}
          ],
          "batch_inputs": [
            {"name":"enc_tokens","shape":[2,8],"dtype":"i32"}
          ],
          "param_count": {"embedding": 0, "non_embedding": 130, "total": 130},
          "flops_per_token": 100.0
        }"#
        .to_string()
    }

    #[test]
    fn parses_meta() {
        let tmp = std::env::temp_dir().join(format!("altup-test-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("meta.json"), fake_meta()).unwrap();
        let a = Artifact::load(&tmp).unwrap();
        assert_eq!(a.params.len(), 2);
        assert_eq!(a.opt_state.len(), 3);
        assert_eq!(a.decode_state.len(), 2);
        assert_eq!(a.decode_state[0].shape, vec![8, 8]);
        assert_eq!(a.decode_state[0].dtype, DType::F32);
        assert_eq!(a.decode_state[1].dtype, DType::I32, "dtype honored, not assumed f32");
        assert_eq!(a.param_elems(), 8 * 16 + 2);
        assert_eq!(a.config.d_model, 8);
        assert!(a.has("train_step"));
        assert!(!a.has("eval_step"));
        assert!(a.draft.is_none(), "no draft entry: spec decoding unavailable");
        assert!(a.paged.is_none(), "no paged entry: monolithic decode state");
        std::fs::remove_dir_all(&tmp).unwrap();
    }

    #[test]
    fn parses_optional_paged_spec() {
        let tmp = std::env::temp_dir().join(format!("altup-test4-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let with_paged = fake_meta().replace(
            "\"flops_per_token\": 100.0",
            "\"flops_per_token\": 100.0, \"paged\": {\"page_size\": 8}",
        );
        std::fs::write(tmp.join("meta.json"), with_paged).unwrap();
        assert_eq!(Artifact::load(&tmp).unwrap().paged.unwrap().page_size, 8);

        // page_size defaults to 16 when the object is present but bare.
        let bare = fake_meta().replace(
            "\"flops_per_token\": 100.0",
            "\"flops_per_token\": 100.0, \"paged\": {}",
        );
        std::fs::write(tmp.join("meta.json"), bare).unwrap();
        assert_eq!(Artifact::load(&tmp).unwrap().paged.unwrap().page_size, 16);
        // Present-but-malformed page_size is a hard error, not a 16.
        for bad in ["0", "-4", "\"16\""] {
            let meta = fake_meta().replace(
                "\"flops_per_token\": 100.0",
                &format!("\"flops_per_token\": 100.0, \"paged\": {{\"page_size\": {bad}}}"),
            );
            std::fs::write(tmp.join("meta.json"), meta).unwrap();
            assert!(Artifact::load(&tmp).is_err(), "paged.page_size {bad} rejected");
        }
        std::fs::remove_dir_all(&tmp).unwrap();
    }

    #[test]
    fn parses_optional_sharding_spec() {
        let tmp = std::env::temp_dir().join(format!("altup-test6-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let with_sharding = fake_meta().replace(
            "\"flops_per_token\": 100.0",
            "\"flops_per_token\": 100.0, \"sharding\": {\"tp\": 4}",
        );
        std::fs::write(tmp.join("meta.json"), with_sharding).unwrap();
        assert_eq!(Artifact::load(&tmp).unwrap().sharding.unwrap().tp, 4);

        // Absent entry means unsharded; bare object defaults to tp=2.
        std::fs::write(tmp.join("meta.json"), fake_meta()).unwrap();
        assert!(Artifact::load(&tmp).unwrap().sharding.is_none());
        let bare = fake_meta().replace(
            "\"flops_per_token\": 100.0",
            "\"flops_per_token\": 100.0, \"sharding\": {}",
        );
        std::fs::write(tmp.join("meta.json"), bare).unwrap();
        assert_eq!(Artifact::load(&tmp).unwrap().sharding.unwrap().tp, 2);
        // Present-but-malformed tp is a hard error, not a silent 2:
        // tp=1 would claim a sharded contract with no shard files.
        for bad in ["0", "1", "-2", "\"2\""] {
            let meta = fake_meta().replace(
                "\"flops_per_token\": 100.0",
                &format!("\"flops_per_token\": 100.0, \"sharding\": {{\"tp\": {bad}}}"),
            );
            std::fs::write(tmp.join("meta.json"), meta).unwrap();
            assert!(Artifact::load(&tmp).is_err(), "sharding.tp {bad} rejected");
        }
        std::fs::remove_dir_all(&tmp).unwrap();
    }

    #[test]
    fn parses_optional_draft_spec() {
        let tmp = std::env::temp_dir().join(format!("altup-test3-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let with_draft = fake_meta().replace(
            "\"flops_per_token\": 100.0",
            "\"flops_per_token\": 100.0,\n          \
             \"draft\": {\"artifact\": \"t-lite\", \"gamma\": 3}",
        );
        std::fs::write(tmp.join("meta.json"), with_draft).unwrap();
        let a = Artifact::load(&tmp).unwrap();
        let d = a.draft.expect("draft entry parsed");
        assert_eq!(d.artifact, "t-lite");
        assert_eq!(d.gamma, 3);

        // gamma defaults to 4 when absent; gamma 0 is rejected.
        let no_gamma = fake_meta().replace(
            "\"flops_per_token\": 100.0",
            "\"flops_per_token\": 100.0, \"draft\": {\"artifact\": \"t-lite\"}",
        );
        std::fs::write(tmp.join("meta.json"), no_gamma).unwrap();
        assert_eq!(Artifact::load(&tmp).unwrap().draft.unwrap().gamma, 4);
        // Present-but-malformed gamma is a hard error, not a silent 4.
        for bad in ["0", "-2", "\"8\""] {
            let meta = fake_meta().replace(
                "\"flops_per_token\": 100.0",
                &format!(
                    "\"flops_per_token\": 100.0, \
                     \"draft\": {{\"artifact\": \"t-lite\", \"gamma\": {bad}}}"
                ),
            );
            std::fs::write(tmp.join("meta.json"), meta).unwrap();
            assert!(Artifact::load(&tmp).is_err(), "draft.gamma {bad} rejected");
        }
        std::fs::remove_dir_all(&tmp).unwrap();
    }

    #[test]
    fn version_and_fingerprint_identity() {
        let tmp = std::env::temp_dir().join(format!("altup-test5-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("meta.json"), fake_meta()).unwrap();
        let a = Artifact::load(&tmp).unwrap();
        assert_eq!(a.version, "unversioned", "absent version entry gets the default label");
        let again = Artifact::load(&tmp).unwrap();
        assert_eq!(a.fingerprint, again.fingerprint, "fingerprint is a pure function of meta");

        let versioned = fake_meta().replace(
            "\"flops_per_token\": 100.0",
            "\"flops_per_token\": 100.0, \"version\": \"v2-recycled\"",
        );
        std::fs::write(tmp.join("meta.json"), versioned).unwrap();
        let b = Artifact::load(&tmp).unwrap();
        assert_eq!(b.version, "v2-recycled");
        assert_ne!(a.fingerprint, b.fingerprint, "any meta change moves the identity");
        std::fs::remove_dir_all(&tmp).unwrap();
    }

    #[test]
    fn checksums_verified_on_load() {
        use crate::runtime::pages::fnv1a_bytes;
        let tmp = std::env::temp_dir().join(format!("altup-test6-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let hlo = b"HloModule train_step\nENTRY main { ROOT r = f32[] constant(0) }\n";
        std::fs::write(tmp.join("train_step.hlo.txt"), hlo).unwrap();
        let good = format!("{:016x}", fnv1a_bytes(hlo));
        let with_sums = |sum: &str| {
            fake_meta().replace(
                "\"flops_per_token\": 100.0",
                &format!("\"flops_per_token\": 100.0, \"checksums\": {{\"train_step\": \"{sum}\"}}"),
            )
        };

        // Matching checksum loads fine.
        std::fs::write(tmp.join("meta.json"), with_sums(&good)).unwrap();
        Artifact::load(&tmp).expect("intact HLO passes its checksum");

        // Truncated HLO (the classic partial-copy deploy failure) is a
        // typed load error that names the file, not a later panic.
        std::fs::write(tmp.join("train_step.hlo.txt"), &hlo[..hlo.len() / 2]).unwrap();
        let err = format!("{:#}", Artifact::load(&tmp).unwrap_err());
        assert!(err.contains("checksum mismatch"), "got: {err}");
        assert!(err.contains("train_step"), "got: {err}");

        // Single flipped byte (corruption) is also caught.
        let mut corrupt = hlo.to_vec();
        corrupt[10] ^= 0x40;
        std::fs::write(tmp.join("train_step.hlo.txt"), &corrupt).unwrap();
        assert!(Artifact::load(&tmp).is_err(), "bit-flip caught");

        // Restore the file: a checksum naming no artifacts entry and a
        // malformed (non-hex) checksum are both load errors.
        std::fs::write(tmp.join("train_step.hlo.txt"), hlo).unwrap();
        let orphan = fake_meta().replace(
            "\"flops_per_token\": 100.0",
            "\"flops_per_token\": 100.0, \"checksums\": {\"decode_step\": \"0123456789abcdef\"}",
        );
        std::fs::write(tmp.join("meta.json"), orphan).unwrap();
        assert!(Artifact::load(&tmp).is_err(), "checksum for unknown HLO rejected");
        std::fs::write(tmp.join("meta.json"), with_sums("not-hex")).unwrap();
        assert!(Artifact::load(&tmp).is_err(), "malformed checksum string rejected");

        // Missing HLO file named by a checksum is a load error too.
        std::fs::remove_file(tmp.join("train_step.hlo.txt")).unwrap();
        std::fs::write(tmp.join("meta.json"), with_sums(&good)).unwrap();
        assert!(Artifact::load(&tmp).is_err(), "missing HLO caught at load");
        std::fs::remove_dir_all(&tmp).unwrap();
    }

    #[test]
    fn param_shape_mismatches_rejected() {
        let tmp = std::env::temp_dir().join(format!("altup-test7-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        // Declared param_count.total disagreeing with the params table
        // is a load error (the compile path pins them equal).
        let drift = fake_meta().replace("\"total\": 130", "\"total\": 131");
        std::fs::write(tmp.join("meta.json"), drift).unwrap();
        let err = format!("{:#}", Artifact::load(&tmp).unwrap_err());
        assert!(err.contains("param_count.total"), "got: {err}");
        // A non-integer dim (parses as 0) is a load error, not an
        // allocation panic at first execute.
        let zero = fake_meta().replace("\"shape\":[8,16]", "\"shape\":[8,\"x\"]");
        std::fs::write(tmp.join("meta.json"), zero).unwrap();
        let err = format!("{:#}", Artifact::load(&tmp).unwrap_err());
        assert!(err.contains("malformed shape"), "got: {err}");
        std::fs::remove_dir_all(&tmp).unwrap();
    }

    #[test]
    fn unsorted_params_rejected() {
        let tmp = std::env::temp_dir().join(format!("altup-test2-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let bad = fake_meta().replace("a/w", "z/w");
        std::fs::write(tmp.join("meta.json"), bad).unwrap();
        assert!(Artifact::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).unwrap();
    }
}
