//! Paged decode-state pool + cross-request prefix cache (§Perf L9).
//!
//! L4–L8 gave every continuous-batching slot a monolithic KV buffer
//! sized to its bucket, so replica memory — not compute — capped
//! slots-per-replica, and requests sharing a system prompt re-ran
//! prefill from token zero. This module pages the decode state instead
//! (Pope et al., "Efficiently Scaling Transformer Inference"; vLLM's
//! PagedAttention): a replica owns one fixed-size pool of KV pages,
//! each slot maps its logical token range onto pool pages through a
//! page table, and page refcounts let several slots share the physical
//! pages of a common prompt prefix.
//!
//! Three host-side pieces, all backend-agnostic (the Sim engine uses
//! them for its memory model; a real artifact consumes the same tables
//! as `prefill_paged`/`decode_token_paged` operands — see the §L9
//! contract in `runtime::session`):
//!
//! - [`PagePool`]: free-list allocator over `capacity` pages of
//!   `page_size` tokens each, with per-page refcounts. Allocation is
//!   LIFO (last freed, first reused) so hot device memory is recycled
//!   before cold.
//! - [`PageTable`]: a slot's logical-page -> pool-page mapping. Grows
//!   as decode crosses bucket/page boundaries; releases every mapped
//!   page back to the pool when the slot retires.
//! - [`PrefixCache`]: content-addressed index from chained page-chunk
//!   hashes ([`chunk_hashes`]) to pool pages. A hit pins the page into
//!   the requesting slot's table (refcount + 1) and skips that chunk of
//!   prefill; unpinned entries (refcount back to 1, i.e. only the cache
//!   holds them) are evicted LRU-first under pool pressure via the
//!   shared [`EvictionPolicy`].
//!
//! Refcount protocol: `alloc` hands out a page at refcount 1 (the
//! owning slot). Inserting it into the prefix cache retains it to 2; a
//! later hit retains once per sharing slot. A slot retiring releases
//! its whole table; a cache eviction releases the cache's reference.
//! The page returns to the free list exactly when the count reaches 0,
//! and a release past 0 is a hard error (double free).

use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

use crate::util::lru::{EvictionPolicy, LruPolicy};

/// Index of a physical page in a replica's pool.
pub type PageId = usize;

/// Pages needed to hold `tokens` positions at `page_size` tokens/page.
pub fn pages_for(tokens: usize, page_size: usize) -> usize {
    let ps = page_size.max(1);
    (tokens + ps - 1) / ps
}

/// Plain FNV-1a over raw bytes — the same constants as `chunk_hashes`,
/// applied bytewise. Used by `runtime::artifact` to verify optional
/// per-HLO-file checksums at load time (§L11 artifact hardening), so a
/// truncated or corrupted HLO is rejected before a replica ever
/// executes it.
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Chained FNV-1a hashes of `tokens` in full `page_size` chunks:
/// entry `k` hashes the first `(k+1) * page_size` tokens, so equal
/// hash `k` means equal *prefix* through page `k` — exactly the
/// property a prefix cache needs (same constants and per-token step as
/// the coordinator's `sim_row_hash`, so sim parity checks can reason
/// about both). The trailing partial chunk is never hashed: a page is
/// only shareable once every position in it is fixed by the prompt.
pub fn chunk_hashes(tokens: &[i32], page_size: usize) -> Vec<u64> {
    let ps = page_size.max(1);
    let chunks = tokens.len() / ps;
    let mut out = Vec::with_capacity(chunks);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for (i, &t) in tokens[..chunks * ps].iter().enumerate() {
        h = (h ^ t as u32 as u64).wrapping_mul(0x0000_0100_0000_01b3);
        if (i + 1) % ps == 0 {
            out.push(h);
        }
    }
    out
}

/// Fixed-size pool of refcounted KV pages with a LIFO free list.
#[derive(Debug)]
pub struct PagePool {
    page_size: usize,
    /// Per-page reference count; 0 means the page is on the free list.
    refcount: Vec<u32>,
    /// Free pages, last-freed on top.
    free: Vec<PageId>,
}

impl PagePool {
    pub fn new(page_size: usize, capacity: usize) -> PagePool {
        PagePool {
            page_size: page_size.max(1),
            refcount: vec![0; capacity],
            // Reverse so the first alloc hands out page 0 — makes
            // allocation order (and tests) readable.
            free: (0..capacity).rev().collect(),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn capacity(&self) -> usize {
        self.refcount.len()
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.capacity() - self.free.len()
    }

    pub fn refcount(&self, page: PageId) -> u32 {
        self.refcount[page]
    }

    /// Take a page off the free list at refcount 1, or `None` when the
    /// pool is exhausted (the caller stalls or sheds — see the
    /// coordinator's admission gate).
    pub fn alloc(&mut self) -> Option<PageId> {
        let page = self.free.pop()?;
        debug_assert_eq!(self.refcount[page], 0, "free-listed page with live refs");
        self.refcount[page] = 1;
        Some(page)
    }

    /// Add a reference to an allocated page (prefix sharing).
    pub fn retain(&mut self, page: PageId) -> Result<()> {
        ensure!(page < self.capacity(), "retain of out-of-range page {page}");
        ensure!(self.refcount[page] > 0, "retain of free page {page}");
        self.refcount[page] += 1;
        Ok(())
    }

    /// Drop a reference; returns `true` when this release freed the
    /// page. Releasing a page that is already free is a double free and
    /// a hard error — the bug class this would mask (two owners both
    /// writing a recycled page) corrupts decode state silently.
    pub fn release(&mut self, page: PageId) -> Result<bool> {
        ensure!(page < self.capacity(), "release of out-of-range page {page}");
        if self.refcount[page] == 0 {
            bail!("double free of page {page}");
        }
        self.refcount[page] -= 1;
        if self.refcount[page] == 0 {
            self.free.push(page);
            return Ok(true);
        }
        Ok(false)
    }
}

/// One slot's logical-page -> pool-page mapping. Entry `k` backs token
/// positions `[k * page_size, (k+1) * page_size)`.
#[derive(Debug, Default)]
pub struct PageTable {
    pages: Vec<PageId>,
}

impl PageTable {
    pub fn new() -> PageTable {
        PageTable { pages: Vec::new() }
    }

    pub fn pages(&self) -> &[PageId] {
        &self.pages
    }

    pub fn len(&self) -> usize {
        self.pages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    /// Map an already-allocated page shared with another owner: takes
    /// an extra reference and appends it (prefix-cache hits land here,
    /// in prompt order, before `ensure` fills the private remainder).
    pub fn push_shared(&mut self, pool: &mut PagePool, page: PageId) -> Result<()> {
        pool.retain(page)?;
        self.pages.push(page);
        Ok(())
    }

    /// Grow the table to at least `pages` entries by allocating private
    /// pages — how a slot crosses a bucket/page boundary mid-decode.
    /// Returns `false` (leaving the partial growth mapped, so `release`
    /// still returns everything) when the pool runs out first.
    pub fn ensure(&mut self, pool: &mut PagePool, pages: usize) -> bool {
        while self.pages.len() < pages {
            match pool.alloc() {
                Some(p) => self.pages.push(p),
                None => return false,
            }
        }
        true
    }

    /// Release every mapped page back to the pool (slot retirement).
    pub fn release(&mut self, pool: &mut PagePool) -> Result<()> {
        for page in self.pages.drain(..) {
            pool.release(page)?;
        }
        Ok(())
    }
}

/// Content-addressed prefix-page index: chained chunk hash -> pool
/// page, with LRU eviction of unpinned entries. Counters (hits,
/// lookups, tokens saved, evictions) live with the caller's
/// `PoolMeter` — the cache answers queries, the serving loop accounts.
#[derive(Debug, Default)]
pub struct PrefixCache {
    entries: HashMap<u64, PageId>,
    order: LruPolicy<u64>,
}

impl PrefixCache {
    pub fn new() -> PrefixCache {
        PrefixCache { entries: HashMap::new(), order: LruPolicy::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Longest cached prefix: how many leading entries of `hashes` are
    /// present. Chained hashes make a single miss terminal — hash `k`
    /// can only match if pages `0..k` match too. Pure peek: the caller
    /// commits hits (refcounts, recency, counters) only once admission
    /// is certain.
    pub fn match_len(&self, hashes: &[u64]) -> usize {
        hashes.iter().take_while(|h| self.entries.contains_key(h)).count()
    }

    /// The page backing a chunk hash, bumping its recency (commit-side
    /// of a hit; pair with `PageTable::push_shared`).
    pub fn hit(&mut self, hash: u64) -> Option<PageId> {
        let page = *self.entries.get(&hash)?;
        self.order.note_touch(hash);
        Some(page)
    }

    /// Index a freshly prefilled page under its chunk hash, taking the
    /// cache's own reference (refcount 2: owner slot + cache). A hash
    /// already present keeps its existing page — identical content, and
    /// the first owner's sharers already point at it.
    pub fn insert(&mut self, pool: &mut PagePool, hash: u64, page: PageId) -> Result<()> {
        if self.entries.contains_key(&hash) {
            return Ok(());
        }
        pool.retain(page)?;
        self.entries.insert(hash, page);
        self.order.note_insert(hash);
        Ok(())
    }

    /// Evict the least-recently-used *unpinned* entry (refcount 1 —
    /// only the cache holds the page; any live slot reference pins it)
    /// and free its page. Returns `false` when everything left is
    /// pinned, i.e. eviction cannot make more room.
    pub fn evict_lru(&mut self, pool: &mut PagePool) -> Result<bool> {
        let entries = &self.entries;
        let victim = self
            .order
            .victim(&|h| entries.get(&h).is_some_and(|&p| pool.refcount(p) == 1));
        let Some(hash) = victim else { return Ok(false) };
        let page = self.entries.remove(&hash).expect("victim came from entries");
        self.order.note_remove(hash);
        pool.release(page)?;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_exhaustion_and_free_reuse() {
        let mut pool = PagePool::new(16, 3);
        assert_eq!(pool.capacity(), 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert_eq!((a, b, c), (0, 1, 2), "fresh pool allocates in order");
        assert_eq!(pool.alloc(), None, "exhausted pool returns None");
        assert_eq!(pool.free_pages(), 0);
        assert!(pool.release(b).unwrap());
        assert_eq!(pool.alloc(), Some(b), "freed page becomes allocatable");
        assert_eq!(pool.used_pages(), 3);
    }

    #[test]
    fn free_list_is_lifo() {
        let mut pool = PagePool::new(16, 4);
        let pages: Vec<PageId> = (0..4).map(|_| pool.alloc().unwrap()).collect();
        pool.release(pages[1]).unwrap();
        pool.release(pages[3]).unwrap();
        // Last freed (3) is reused first, then 1.
        assert_eq!(pool.alloc(), Some(pages[3]));
        assert_eq!(pool.alloc(), Some(pages[1]));
    }

    #[test]
    fn double_free_rejected() {
        let mut pool = PagePool::new(16, 2);
        let p = pool.alloc().unwrap();
        assert!(pool.release(p).unwrap());
        let err = pool.release(p).unwrap_err().to_string();
        assert!(err.contains("double free"), "got: {err}");
        assert!(pool.release(99).is_err(), "out-of-range release rejected");
        assert!(pool.retain(p).is_err(), "retain of a free page rejected");
    }

    #[test]
    fn refcounted_release_frees_on_last_owner() {
        let mut pool = PagePool::new(16, 2);
        let p = pool.alloc().unwrap();
        pool.retain(p).unwrap();
        pool.retain(p).unwrap();
        assert_eq!(pool.refcount(p), 3);
        assert!(!pool.release(p).unwrap());
        assert!(!pool.release(p).unwrap());
        assert_eq!(pool.free_pages(), 1, "still held by the last owner");
        assert!(pool.release(p).unwrap(), "final release frees");
        assert_eq!(pool.free_pages(), 2);
    }

    #[test]
    fn page_table_grows_across_bucket_boundaries() {
        // A slot prefilled at bucket 16 (2 pages of 8) decodes past the
        // bucket edge: the table grows page-by-page, never re-mapping
        // what's already resident.
        let mut pool = PagePool::new(8, 8);
        let mut table = PageTable::new();
        assert!(table.ensure(&mut pool, pages_for(16, 8)));
        assert_eq!(table.len(), 2);
        let before = table.pages().to_vec();
        assert!(table.ensure(&mut pool, pages_for(16 + 24, 8)), "grow to 5 pages");
        assert_eq!(table.len(), 5);
        assert_eq!(&table.pages()[..2], &before[..], "resident mapping stable");
        assert!(table.ensure(&mut pool, 5), "no-op growth succeeds");
        assert_eq!(pool.used_pages(), 5);
        table.release(&mut pool).unwrap();
        assert_eq!(pool.used_pages(), 0);
        assert!(table.is_empty());
    }

    #[test]
    fn page_table_partial_growth_stays_released_once() {
        let mut pool = PagePool::new(8, 2);
        let mut table = PageTable::new();
        assert!(!table.ensure(&mut pool, 5), "pool too small");
        assert_eq!(table.len(), 2, "partial growth stays mapped");
        table.release(&mut pool).unwrap();
        assert_eq!(pool.free_pages(), 2, "partial growth fully returned");
    }

    #[test]
    fn deterministic_fragmentation_scenario() {
        // Interleaved slot lifetimes fragment the pool; the free list
        // must recycle exactly the holes, LIFO, with used/free always
        // consistent. Fixed pattern -> fully deterministic.
        let mut pool = PagePool::new(16, 6);
        let mut t = Vec::new();
        for _ in 0..3 {
            let mut table = PageTable::new();
            assert!(table.ensure(&mut pool, 2));
            t.push(table);
        }
        assert_eq!(pool.free_pages(), 0);
        // Retire the middle slot: pages 2,3 become the hole.
        t[1].release(&mut pool).unwrap();
        assert_eq!(pool.free_pages(), 2);
        // A 3-page request cannot fit the hole...
        let mut big = PageTable::new();
        assert!(!big.ensure(&mut pool, 3));
        big.release(&mut pool).unwrap();
        // ...but after the first slot retires too (pages 0,1), it can,
        // and it reuses the most recently freed pages first.
        t[0].release(&mut pool).unwrap();
        assert!(big.ensure(&mut pool, 3));
        assert_eq!(big.pages(), &[1, 0, 3], "LIFO reuse of the freed holes");
        assert_eq!(pool.used_pages(), 5);
    }

    #[test]
    fn chunk_hashes_match_on_shared_prefix_only() {
        let header: Vec<i32> = (2..18).collect(); // two full 8-token pages
        let a: Vec<i32> = header.iter().copied().chain([100, 101, 102, 103, 104, 105, 106, 107]).collect();
        let b: Vec<i32> = header.iter().copied().chain([200, 201, 202, 203, 204, 205, 206, 207]).collect();
        let ha = chunk_hashes(&a, 8);
        let hb = chunk_hashes(&b, 8);
        assert_eq!(ha.len(), 3);
        assert_eq!(ha[..2], hb[..2], "shared header chunks hash equal");
        assert_ne!(ha[2], hb[2], "divergent tails hash differently");
        // Partial trailing chunk is not hashed.
        assert_eq!(chunk_hashes(&a[..12], 8).len(), 1);
        assert_eq!(chunk_hashes(&[], 8).len(), 0);
    }

    #[test]
    fn prefix_cache_hit_and_match_len() {
        let mut pool = PagePool::new(8, 4);
        let mut cache = PrefixCache::new();
        let prompt: Vec<i32> = (2..26).collect(); // 3 full pages of 8
        let hashes = chunk_hashes(&prompt, 8);
        assert_eq!(cache.match_len(&hashes), 0);

        // First request prefills all 3 pages and indexes them.
        let mut t1 = PageTable::new();
        assert!(t1.ensure(&mut pool, 3));
        for (i, &h) in hashes.iter().enumerate() {
            cache.insert(&mut pool, h, t1.pages()[i]).unwrap();
        }
        assert_eq!(cache.match_len(&hashes), 3);

        // Second request shares all 3 pages instead of allocating.
        let mut t2 = PageTable::new();
        for &h in &hashes {
            let page = cache.hit(h).unwrap();
            t2.push_shared(&mut pool, page).unwrap();
        }
        assert_eq!(t2.pages(), t1.pages());
        assert_eq!(pool.used_pages(), 3, "no new pages for the sharer");
        assert_eq!(pool.refcount(t1.pages()[0]), 3, "slot + slot + cache");

        // Retiring both slots leaves cache-only refs; pages stay resident.
        t1.release(&mut pool).unwrap();
        t2.release(&mut pool).unwrap();
        assert_eq!(pool.used_pages(), 3);
        assert!(hashes.iter().all(|&h| pool.refcount(cache.hit(h).unwrap()) == 1));
    }

    #[test]
    fn eviction_is_lru_and_never_touches_pinned_pages() {
        let mut pool = PagePool::new(8, 4);
        let mut cache = PrefixCache::new();
        let mut table = PageTable::new();
        assert!(table.ensure(&mut pool, 3));
        for (i, &page) in table.pages().to_vec().iter().enumerate() {
            cache.insert(&mut pool, 1000 + i as u64, page).unwrap();
        }
        // All pages pinned by the live slot: nothing evictable.
        assert!(!cache.evict_lru(&mut pool).unwrap());
        assert_eq!(cache.len(), 3);

        // Slot retires; touch entry 1000 so 1001 becomes LRU.
        let pages = table.pages().to_vec();
        table.release(&mut pool).unwrap();
        cache.hit(1000).unwrap();
        assert!(cache.evict_lru(&mut pool).unwrap());
        assert_eq!(cache.match_len(&[1001]), 0, "LRU entry evicted first");
        assert_eq!(pool.refcount(pages[1]), 0, "evicted page freed");

        // Re-pin 1002 via a new sharer: only 1000 remains evictable.
        let mut t2 = PageTable::new();
        t2.push_shared(&mut pool, cache.hit(1002).unwrap()).unwrap();
        assert!(cache.evict_lru(&mut pool).unwrap());
        assert_eq!(cache.match_len(&[1000]), 0);
        assert!(!cache.evict_lru(&mut pool).unwrap(), "pinned survivor stays");
        assert_eq!(cache.match_len(&[1002]), 1);
        t2.release(&mut pool).unwrap();
    }

    #[test]
    fn insert_of_existing_hash_keeps_first_page() {
        let mut pool = PagePool::new(8, 4);
        let mut cache = PrefixCache::new();
        let p0 = pool.alloc().unwrap();
        let p1 = pool.alloc().unwrap();
        cache.insert(&mut pool, 42, p0).unwrap();
        cache.insert(&mut pool, 42, p1).unwrap();
        assert_eq!(cache.hit(42), Some(p0), "first page wins");
        assert_eq!(pool.refcount(p1), 1, "duplicate insert takes no extra ref");
    }

    #[test]
    fn pages_for_rounds_up() {
        assert_eq!(pages_for(0, 16), 0);
        assert_eq!(pages_for(1, 16), 1);
        assert_eq!(pages_for(16, 16), 1);
        assert_eq!(pages_for(17, 16), 2);
        assert_eq!(pages_for(128, 16), 8);
        assert_eq!(pages_for(5, 0), 5, "degenerate page size clamps to 1");
    }
}
