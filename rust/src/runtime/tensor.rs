//! Host tensors + conversion to/from PJRT literals.

use anyhow::{bail, Result};

/// Element type of a tensor (the subset our artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn from_str(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            _ => bail!("unsupported dtype {s}"),
        })
    }
    pub fn size(&self) -> usize {
        4
    }
}

/// A host-side dense tensor (row-major).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }
    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::U32(data) }
    }
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }
    pub fn scalar_u32(v: u32) -> Tensor {
        Tensor::u32(vec![], vec![v])
    }
    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
            TensorData::U32(_) => DType::U32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Convert to an xla Literal.
    ///
    /// §Perf (L3): builds the literal in one pass via
    /// `create_from_shape_and_untyped_data` — the naive `vec1(...)
    /// .reshape(...)` path copies every buffer twice, which showed up as
    /// ~40% of marshalling time in the train-step profile (see
    /// EXPERIMENTS.md §Perf).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        fn bytes_of<T>(v: &[T]) -> &[u8] {
            // SAFETY: plain-old-data element types, little-endian host.
            unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
            }
        }
        let (ty, bytes): (xla::ElementType, &[u8]) = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                (xla::ElementType::F32, bytes_of(v))
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                (xla::ElementType::S32, bytes_of(v))
            }
            TensorData::U32(v) => {
                if self.shape.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                (xla::ElementType::U32, bytes_of(v))
            }
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(ty, &self.shape, bytes)?)
    }

    /// Read back from an xla Literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let ty = shape.primitive_type();
        let t = match ty {
            xla::PrimitiveType::F32 => Tensor::f32(dims, lit.to_vec::<f32>()?),
            xla::PrimitiveType::S32 => Tensor::i32(dims, lit.to_vec::<i32>()?),
            xla::PrimitiveType::U32 => Tensor::u32(dims, lit.to_vec::<u32>()?),
            other => bail!("unsupported literal type {other:?}"),
        };
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_checked() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        let _ = Tensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn dtype_strings() {
        assert_eq!(DType::from_str("f32").unwrap(), DType::F32);
        assert!(DType::from_str("f64").is_err());
    }
}
