//! Analytic model accounting (parameter counts, FLOPs) shared by the
//! experiment harnesses and the roofline simulator.

pub mod counting;
