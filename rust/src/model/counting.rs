//! Analytic parameter counting + FLOPs for every variant, at any scale —
//! including the paper's real T5 configs where no artifact exists.
//!
//! This is the source for Table 3/4/5's parameter columns. The counting
//! formulas exactly mirror `python/compile/model.py::param_specs` (unit
//! tests cross-check against artifact meta.json at testbed scale), with
//! one switch: `t5_paper_accounting` reproduces the *paper's* embedding
//! convention (input table + output head, no relpos/altup bookkeeping
//! differences at their scale).

use crate::config::{ModelConfig, Variant};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParamCount {
    pub embedding: usize,
    pub non_embedding: usize,
}

impl ParamCount {
    pub fn total(&self) -> usize {
        self.embedding + self.non_embedding
    }
}

/// Count parameters for a config, mirroring python's param_specs.
pub fn count_params(cfg: &ModelConfig) -> ParamCount {
    let d = cfg.layer_width();
    let widen = if cfg.variant == Variant::DenseWide { cfg.k } else { 1 };
    let f = cfg.d_ff * widen;
    let inner = cfg.num_heads * cfg.d_head * widen;
    let v = cfg.vocab_size;

    let embed_width = match cfg.variant {
        Variant::AltUp | Variant::SameUp | Variant::Sum | Variant::DenseWide => {
            cfg.k * cfg.d_model
        }
        _ => cfg.d_model,
    };
    let head_in = match cfg.variant {
        Variant::AltUp | Variant::SameUp | Variant::DenseWide => cfg.k * cfg.d_model,
        _ => cfg.d_model, // baseline, sum, recycled, sequence variants
    };
    let embedding = v * embed_width + head_in * v;

    let mut per_layer_enc = 0usize;
    // ln_attn + attn qkvo + ln_ffn + ffn
    per_layer_enc += d; // ln_attn
    per_layer_enc += 3 * d * inner + inner * d;
    per_layer_enc += d; // ln_ffn
    per_layer_enc += 2 * d * f + f * d;
    let mut per_layer_dec = per_layer_enc;
    per_layer_dec += d; // ln_cross
    per_layer_dec += 3 * d * inner + inner * d;

    let mut extras_per_layer = 0usize;
    if cfg.moe {
        extras_per_layer += d * cfg.moe_experts + 2 * cfg.moe_experts * d * cfg.moe_hidden;
    }
    if cfg.variant.is_block_widened() {
        extras_per_layer += cfg.k * cfg.k + cfg.k; // p + g
    }
    if cfg.variant == Variant::SeqAltUp {
        extras_per_layer += 3; // a1, a2, b
    }

    let relpos = 2 * cfg.rel_pos_buckets * cfg.num_heads;
    let final_lns = 2 * d;
    let non_embedding = cfg.enc_layers * (per_layer_enc + extras_per_layer)
        + cfg.dec_layers * (per_layer_dec + extras_per_layer)
        + relpos
        + final_lns;

    ParamCount { embedding, non_embedding }
}

/// Forward FLOPs per sequence (encoder + decoder), used by the roofline.
pub fn forward_flops(cfg: &ModelConfig) -> f64 {
    let d = cfg.layer_width() as f64;
    let widen = if cfg.variant == Variant::DenseWide { cfg.k } else { 1 } as f64;
    let f = cfg.d_ff as f64 * widen;
    let inner = (cfg.num_heads * cfg.d_head) as f64 * widen;
    let te = cfg.enc_len as f64;
    let td = cfg.dec_len as f64;
    let v = cfg.vocab_size as f64;

    // Sequence-length reduction variants shrink the effective encoder
    // length in the reduced window.
    let enc_window = |i: usize| -> f64 {
        match cfg.variant {
            Variant::AvgPool => te / cfg.seq_stride as f64,
            Variant::SeqAltUp | Variant::StrideSkip => {
                if i >= 1 && i + 1 < cfg.enc_layers {
                    te / cfg.seq_stride as f64
                } else {
                    te
                }
            }
            _ => te,
        }
    };

    let layer_flops = |tokens: f64, kv_tokens: f64, cross: bool| -> f64 {
        let attn_proj = 2.0 * tokens * (4.0 * d * inner);
        let attn_mix = 2.0 * 2.0 * tokens * kv_tokens * inner;
        let ffn = 2.0 * tokens * 3.0 * d * f;
        let cross_cost = if cross {
            2.0 * tokens * (4.0 * d * inner) + 2.0 * 2.0 * tokens * te * inner
        } else {
            0.0
        };
        attn_proj + attn_mix + ffn + cross_cost
    };

    let mut total = 0.0;
    for i in 0..cfg.enc_layers {
        let t = enc_window(i);
        total += layer_flops(t, t, false);
        if cfg.variant.is_block_widened() {
            // predict+correct: K^2+K scalar-vector ops over d per token
            total += 2.0 * te * d * ((cfg.k * cfg.k + cfg.k) as f64);
        }
    }
    for _ in 0..cfg.dec_layers {
        total += layer_flops(td, td, true);
        if cfg.variant.is_block_widened() {
            total += 2.0 * td * d * ((cfg.k * cfg.k + cfg.k) as f64);
        }
    }
    // Output head.
    let head_in = match cfg.variant {
        Variant::AltUp | Variant::SameUp | Variant::DenseWide => (cfg.k * cfg.d_model) as f64,
        _ => cfg.d_model as f64,
    };
    total += 2.0 * td * head_in * v;
    total
}

/// Training-step FLOPs ~= 3x forward (fwd + bwd).
pub fn train_flops(cfg: &ModelConfig) -> f64 {
    3.0 * forward_flops(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_preset;

    #[test]
    fn paper_table3_small() {
        // Paper Table 3: S has 3.29e7 embedding params.
        let c = paper_preset("S", Variant::Baseline, 2);
        let p = count_params(&c);
        let emb = p.embedding as f64;
        assert!((emb - 3.29e7).abs() / 3.29e7 < 0.01, "emb={emb:.3e}");
        // S + AltUp: 6.58e7 embedding.
        let ca = paper_preset("S", Variant::AltUp, 2);
        let pa = count_params(&ca);
        assert!((pa.embedding as f64 - 6.58e7).abs() / 6.58e7 < 0.01);
    }

    #[test]
    fn paper_table3_base_large() {
        let b = count_params(&paper_preset("B", Variant::Baseline, 2));
        assert!((b.embedding as f64 - 4.93e7).abs() / 4.93e7 < 0.01, "{:e}", b.embedding as f64);
        // non-emb ~1.98e8 for B (paper) — ours should be within ~15%
        // (theirs includes minor extras); the *ratio* to AltUp matters.
        assert!((b.non_embedding as f64 - 1.98e8).abs() / 1.98e8 < 0.2, "{:e}", b.non_embedding as f64);
        let l = count_params(&paper_preset("L", Variant::Baseline, 2));
        assert!((l.embedding as f64 - 6.58e7).abs() / 6.58e7 < 0.01);
        assert!((l.non_embedding as f64 - 7.17e8).abs() / 7.17e8 < 0.2, "{:e}", l.non_embedding as f64);
    }

    #[test]
    fn paper_table5_xl() {
        let xl = count_params(&paper_preset("XL", Variant::Baseline, 2));
        assert!((xl.embedding as f64 - 1.32e8).abs() / 1.32e8 < 0.01);
        assert!((xl.non_embedding as f64 - 2.72e9).abs() / 2.72e9 < 0.25, "{:e}", xl.non_embedding as f64);
    }

    #[test]
    fn altup_non_emb_overhead_tiny() {
        // AltUp adds only K^2+K scalars per layer to non-emb.
        let base = count_params(&paper_preset("B", Variant::Baseline, 2));
        let alt = count_params(&paper_preset("B", Variant::AltUp, 2));
        let diff = alt.non_embedding - base.non_embedding;
        assert_eq!(diff, 24 * (4 + 2));
        assert_eq!(alt.embedding, 2 * base.embedding);
    }

    #[test]
    fn dense_scaling_quadruples_non_emb() {
        let base = count_params(&paper_preset("B", Variant::Baseline, 2));
        let d2 = count_params(&paper_preset("B", Variant::DenseWide, 2));
        let ratio = d2.non_embedding as f64 / base.non_embedding as f64;
        assert!((ratio - 4.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn flops_ordering() {
        let base = forward_flops(&paper_preset("B", Variant::Baseline, 2));
        let alt = forward_flops(&paper_preset("B", Variant::AltUp, 2));
        let d2 = forward_flops(&paper_preset("B", Variant::DenseWide, 2));
        assert!(alt < 1.15 * base, "altup {alt:e} vs base {base:e}");
        assert!(d2 > 2.5 * base);
        let rec = forward_flops(&paper_preset("B", Variant::Recycled, 2));
        assert!(rec < alt, "recycled saves the head widening");
    }

    #[test]
    fn seq_variants_save_encoder_flops() {
        let base = forward_flops(&paper_preset("B", Variant::Baseline, 2));
        let seq = forward_flops(&paper_preset("B", Variant::SeqAltUp, 2));
        let pool = forward_flops(&paper_preset("B", Variant::AvgPool, 2));
        assert!(seq < 0.75 * base, "seq={seq:e} base={base:e}");
        assert!(pool < seq);
    }
}
