//! Pipeline-level integration: pretrain->finetune recipe, generative
//! eval, serving, and checkpoint interop — over real artifacts (skips
//! when `make artifacts` hasn't run).

use altup::coordinator::pipeline::{finetune_task, pretrain, PipelineOptions};
use altup::coordinator::server::{ServerHandle, ServerOptions};
use altup::data::tasks::{Task, TaskKind};
use altup::runtime::artifact::{artifacts_root, load_named};
use altup::runtime::client::Client;

fn have_artifacts() -> bool {
    artifacts_root().join("micro-altup/meta.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn quick_opts() -> PipelineOptions {
    PipelineOptions {
        pretrain_steps: 12,
        finetune_steps: 10,
        warmup: 1000,
        eval_batches: 2,
        verbose: false,
        ..Default::default()
    }
}

#[test]
fn pretrain_then_finetune_glue() {
    require_artifacts!();
    let client = Client::cpu().unwrap();
    let artifact = load_named("micro-altup").unwrap();
    let opts = quick_opts();
    let (session, pre_ev, sps, data_wait) = pretrain(&client, artifact, &opts).unwrap();
    assert!(pre_ev.loss.is_finite() && pre_ev.loss > 0.0);
    assert!(sps > 0.0);
    assert!(data_wait >= 0.0);
    let ev = finetune_task(&client, &session, TaskKind::Glue, &opts).unwrap();
    assert!(ev.accuracy >= 0.0 && ev.accuracy <= 1.0);
    assert!(ev.examples > 0);
}

#[test]
fn finetune_squad_reports_em_f1() {
    require_artifacts!();
    let client = Client::cpu().unwrap();
    let artifact = load_named("micro-baseline").unwrap();
    let opts = quick_opts();
    let (session, _, _, _) = pretrain(&client, artifact, &opts).unwrap();
    let ev = finetune_task(&client, &session, TaskKind::Squad, &opts).unwrap();
    assert!((0.0..=1.0).contains(&ev.em));
    assert!((0.0..=1.0).contains(&ev.f1));
    assert!(ev.f1 >= ev.em - 1e-9, "F1 >= EM by construction");
}

#[test]
fn finetune_improves_over_untrained_on_glue() {
    // The task must be learnable: finetuned accuracy should beat the
    // ~50% chance level of the binary label task.
    require_artifacts!();
    let client = Client::cpu().unwrap();
    let artifact = load_named("micro-baseline").unwrap();
    let opts = PipelineOptions {
        pretrain_steps: 30,
        finetune_steps: 60,
        warmup: 1000,
        eval_batches: 4,
        verbose: false,
        ..Default::default()
    };
    let (session, _, _, _) = pretrain(&client, artifact, &opts).unwrap();
    let ev = finetune_task(&client, &session, TaskKind::Glue, &opts).unwrap();
    // Token accuracy on (label, EOS) pairs; chance is well below 0.5.
    assert!(ev.accuracy > 0.4, "accuracy {:.3} not above near-chance", ev.accuracy);
}

#[test]
fn server_batches_and_replies() {
    require_artifacts!();
    let server = ServerHandle::spawn(
        "micro-baseline",
        ServerOptions { batch_window: std::time::Duration::from_millis(20), ..Default::default() },
    );
    let task = Task::new(TaskKind::Squad, 2048, 1);
    // Submit concurrently from two client threads to exercise batching.
    let s1 = server.sender.clone();
    let t1 = std::thread::spawn(move || {
        let task = Task::new(TaskKind::Squad, 2048, 2);
        let mut out = Vec::new();
        for i in 0..6 {
            let (tx, rx) = std::sync::mpsc::channel();
            s1.send(altup::coordinator::server::Request::new(task.example(i, 62).enc, tx))
                .unwrap();
            out.push(rx.recv().unwrap());
        }
        out
    });
    let mut responses = Vec::new();
    for i in 0..6 {
        responses.push(server.infer(task.example(i, 62).enc).unwrap());
    }
    responses.extend(t1.join().unwrap());
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 12);
    assert!(stats.batches <= 12);
    for r in &responses {
        // Rows are EOS-truncated (inclusive) since §Perf L6; 32 is the
        // micro dec_len ceiling.
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 32);
        assert!(r.batch_fill >= 1);
        assert!(!r.truncated, "in-budget prompts must not be flagged truncated");
    }
}

#[test]
fn variant_artifacts_all_trainable_one_step() {
    require_artifacts!();
    let client = Client::cpu().unwrap();
    for name in [
        "micro-sameup",
        "micro-sum",
        "micro-seqaltup",
        "micro-strideskip",
        "micro-avgpool",
        "micro-moe",
        "micro-altup-moe",
        "micro-dense2x",
    ] {
        if !artifacts_root().join(name).join("meta.json").exists() {
            continue;
        }
        let artifact = load_named(name).unwrap();
        let cfg = artifact.config.clone();
        let mut session =
            altup::runtime::session::Session::open(&client, artifact, 0).unwrap();
        let mut b = altup::data::batcher::PretrainBatcher::new(
            cfg.vocab_size,
            cfg.batch_size,
            cfg.enc_len,
            cfg.dec_len,
            1,
        );
        let batch = b.next_batch();
        let m = session.train_step(&client, 1e-3, 1, &batch).unwrap();
        assert!(m.loss.is_finite() && m.loss > 0.0, "{name}: loss={}", m.loss);
        assert!(m.ntok > 0.0, "{name}");
    }
}
