//! Property-based tests over the coordinator's pure substrates
//! (tokenizer, span corruption, batcher, metrics, json) using the
//! in-repo mini property harness (`util::prop` — proptest is not
//! available in the offline image; see DESIGN.md §4).

use altup::data::span::{corrupt, SpanConfig};
use altup::data::tasks::{exact_match, f1_score, Task, TaskKind};
use altup::data::tokenizer::{Tokenizer, EOS, PAD};
use altup::util::json::Json;
use altup::util::prop::{forall, Gen, Pair, TokenSeq, UsizeIn};
use altup::util::rng::Rng;

const CASES: usize = 150;

fn tk() -> Tokenizer {
    Tokenizer::new(2048).unwrap()
}

/// Sequences of content tokens (valid span-corruption input).
fn content_seq(min_len: usize, max_len: usize) -> TokenSeq {
    TokenSeq { vocab: 1500, min_len, max_len }
}

fn to_tokens(tkz: &Tokenizer, words: &[u32]) -> Vec<i32> {
    words.iter().map(|&w| tkz.encode_word(w)).collect()
}

#[test]
fn prop_span_corruption_reconstructs_input() {
    let tkz = tk();
    forall(1, CASES, &Pair(content_seq(4, 160), UsizeIn(0, 1 << 30)), |(words, seed)| {
        let tokens = to_tokens(&tkz, words);
        let mut rng = Rng::new(*seed as u64);
        let ex = corrupt(&tokens, SpanConfig::default(), &tkz, &mut rng);
        // Parse spans out of the target and substitute back.
        let mut spans: Vec<(i32, Vec<i32>)> = Vec::new();
        for &t in tkz.until_eos(&ex.dec_targets) {
            if tkz.is_sentinel(t) {
                spans.push((t, Vec::new()));
            } else if let Some(last) = spans.last_mut() {
                last.1.push(t);
            } else {
                return false; // target must start with a sentinel
            }
        }
        let mut rebuilt = Vec::new();
        for &t in tkz.until_eos(&ex.enc) {
            if tkz.is_sentinel(t) {
                match spans.iter().find(|(s, _)| *s == t) {
                    Some((_, span)) => rebuilt.extend_from_slice(span),
                    None => return false,
                }
            } else {
                rebuilt.push(t);
            }
        }
        rebuilt == tokens
    });
}

#[test]
fn prop_span_corruption_targets_shifted() {
    let tkz = tk();
    forall(2, CASES, &Pair(content_seq(4, 120), UsizeIn(0, 1 << 30)), |(words, seed)| {
        let tokens = to_tokens(&tkz, words);
        let mut rng = Rng::new(*seed as u64);
        let ex = corrupt(&tokens, SpanConfig::default(), &tkz, &mut rng);
        ex.dec_input[0] == PAD
            && ex.dec_input[1..] == ex.dec_targets[..ex.dec_targets.len() - 1]
            && *ex.dec_targets.last().unwrap() == EOS
    });
}

#[test]
fn prop_tokenizer_roundtrip() {
    let tkz = tk();
    forall(3, CASES, &content_seq(1, 64), |words| {
        let ids = tkz.encode_doc(words);
        let back = tkz.content_of(&ids);
        back == *words
    });
}

#[test]
fn prop_tokenizer_specials_never_content() {
    let tkz = tk();
    forall(4, CASES, &UsizeIn(0, 40), |&id| {
        let id = id as i32;
        // ids below FIRST_CONTENT decode to None
        if id < altup::data::tokenizer::FIRST_CONTENT {
            tkz.decode_token(id).is_none()
        } else {
            tkz.decode_token(id).is_some()
        }
    });
}

#[test]
fn prop_f1_bounds_and_symmetry() {
    let gen = Pair(content_seq(1, 12), content_seq(1, 12));
    forall(5, CASES, &gen, |(a, b)| {
        let f = f1_score(a, b);
        let fr = f1_score(b, a);
        (0.0..=1.0).contains(&f) && (f - fr).abs() < 1e-12
    });
}

#[test]
fn prop_em_implies_f1_one() {
    forall(6, CASES, &content_seq(1, 12), |a| {
        exact_match(a, a) == 1.0 && (f1_score(a, a) - 1.0).abs() < 1e-12
    });
}

#[test]
fn prop_task_examples_fit_geometry() {
    // Every task example's decoder side fits dec_len after truncation
    // and keeps input/target alignment.
    let gen = Pair(UsizeIn(0, 3), UsizeIn(0, 5000));
    forall(7, CASES, &gen, |&(kind_idx, index)| {
        let kind = [TaskKind::Glue, TaskKind::SuperGlue, TaskKind::Squad, TaskKind::TriviaQa]
            [kind_idx];
        let task = Task::new(kind, 2048, 17);
        let ex = task.example(index as u64, 62);
        ex.dec_input.len() == ex.dec_targets.len()
            && ex.dec_input[0] == PAD
            && !ex.answer.is_empty()
    });
}

#[test]
fn prop_json_roundtrip_numbers() {
    forall(8, CASES, &UsizeIn(0, 1 << 31), |&n| {
        let src = format!("{{\"v\": {n}, \"a\": [{n}, -{n}]}}");
        let v = Json::parse(&src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        re.get("v").as_i64() == Some(n as i64) && re.get("a").idx(1).as_i64() == Some(-(n as i64))
    });
}

#[test]
fn prop_json_roundtrip_strings() {
    struct Ascii;
    impl Gen for Ascii {
        type Value = String;
        fn draw(&self, rng: &mut Rng) -> String {
            let len = rng.range(0, 24);
            (0..len)
                .map(|_| char::from_u32(rng.range(0x20, 0x7F) as u32).unwrap())
                .collect()
        }
        fn shrink(&self, v: &String) -> Vec<String> {
            if v.is_empty() {
                vec![]
            } else {
                vec![v[..v.len() / 2].to_string(), String::new()]
            }
        }
    }
    forall(9, CASES, &Ascii, |s| {
        let v = Json::Str(s.clone());
        Json::parse(&v.to_string()).map(|r| r.as_str() == Some(s.as_str())).unwrap_or(false)
    });
}

#[test]
fn prop_rng_range_in_bounds() {
    forall(10, CASES, &Pair(UsizeIn(0, 1000), UsizeIn(1, 1000)), |&(lo, span)| {
        let mut rng = Rng::new((lo * 31 + span) as u64);
        let v = rng.range(lo, lo + span);
        v >= lo && v < lo + span
    });
}

#[test]
fn prop_batch_geometry_invariant() {
    use altup::data::batcher::Batch;
    use altup::data::tasks::Example;
    let gen = Pair(UsizeIn(1, 8), Pair(UsizeIn(4, 64), UsizeIn(2, 32)));
    forall(11, 60, &gen, |&(b, (enc_len, dec_len))| {
        let task = Task::new(TaskKind::Glue, 2048, 3);
        let examples: Vec<Example> = (0..b).map(|i| task.example(i as u64, 60)).collect();
        let batch = Batch::from_examples(&examples, b, enc_len, dec_len);
        batch.enc_tokens.len() == b * enc_len
            && batch.dec_input.len() == b * dec_len
            && batch.dec_targets.len() == b * dec_len
            && batch.answers.len() == b
    });
}

/// §L11 satellite: liveness of the full serving stack under composed
/// adversity. Whatever combination of rolling swap (clean or
/// bad-version), replica kill, expired-deadline shedding, and
/// pool-exhaustion pressure a scenario draws, every admitted request
/// gets EXACTLY one terminal `Response` (tokens or a typed failure —
/// never zero, never two), and the rollout itself reaches a terminal
/// `DeployStatus`.
#[test]
fn prop_exactly_one_terminal_response_under_swap_chaos() {
    use altup::coordinator::deploy::DeployOptions;
    use altup::coordinator::server::{
        BadVersionMode, EngineSpec, FaultSpec, Request, ServerHandle, ServerOptions, SimPoolSpec,
        SimSpec, SimSwapSpec,
    };
    use std::time::{Duration, Instant};

    #[derive(Debug, Clone, PartialEq)]
    struct Scenario {
        replicas: usize,
        slots: usize,
        paged: bool,
        kill: bool,
        shed: bool,
        bad: bool,
        /// §L12: serve the fleet as 2-way execution groups instead of
        /// whole-model singles — swaps, kills (landed on a follower
        /// shard), sheds, and pool pressure must all compose with
        /// group-granular supervision.
        tp: bool,
        requests: usize,
    }

    struct ScenarioGen;
    impl Gen for ScenarioGen {
        type Value = Scenario;
        fn draw(&self, rng: &mut Rng) -> Scenario {
            Scenario {
                replicas: rng.range(1, 3),
                slots: rng.range(2, 5),
                paged: rng.range(0, 2) == 1,
                kill: rng.range(0, 2) == 1,
                shed: rng.range(0, 2) == 1,
                bad: rng.range(0, 2) == 1,
                tp: rng.range(0, 2) == 1,
                requests: rng.range(6, 17),
            }
        }
        fn shrink(&self, v: &Scenario) -> Vec<Scenario> {
            [
                Scenario { paged: false, ..v.clone() },
                Scenario { kill: false, ..v.clone() },
                Scenario { shed: false, ..v.clone() },
                Scenario { bad: false, ..v.clone() },
                Scenario { tp: false, ..v.clone() },
                Scenario { replicas: 1, ..v.clone() },
                Scenario { requests: (v.requests / 2).max(2), ..v.clone() },
            ]
            .into_iter()
            .filter(|c| c != v)
            .collect()
        }
    }

    forall(12, 10, &ScenarioGen, |s| {
        let mut spec = SimSpec::new(2, 32, 8);
        spec.vocab_size = 97;
        spec.token_ns = 0;
        spec.dtoken_ns = 0;
        spec.dstep_ns = 0;
        if let Some(d) = spec.draft.as_mut() {
            d.dtoken_ns = 0;
            d.dstep_ns = 0;
        }
        // A pool small enough that concurrent slots can exhaust it.
        spec.pool = if s.paged {
            Some(SimPoolSpec { page_size: 4, pool_pages: 6, prefix_cache: false })
        } else {
            None
        };
        if s.kill {
            spec.fault =
                FaultSpec { kill_replica: Some(0), kill_after_calls: 2, ..FaultSpec::default() };
            if s.tp {
                // Land the kill on the follower shard: the whole group
                // must still die (and respawn) atomically.
                spec.fault.kill_shard = 1;
            }
        }
        let options = ServerOptions {
            batch_window: Duration::from_millis(1),
            seed: 0,
            checkpoint: None,
            replicas: s.replicas,
            bucketed: true,
            slots: s.slots,
            continuous: true,
            queue_cap: 256,
            request_timeout_ms: None,
            max_retries: 3,
            replica_restarts: 6,
            spec_gamma: 0,
            tenants: Vec::new(),
            autoscale: 0,
            restart_backoff_ms: 1,
            // max_err 1.0 / huge lat_factor: only the token-parity
            // probe can fail a canary, so clean swaps promote
            // deterministically even while kills and sheds are flying.
            deploy: DeployOptions {
                probation: 2,
                probation_ms: 40,
                probes: 1,
                max_err: 1.0,
                lat_factor: 1e9,
                hold_ms: 3000,
            },
            tp: if s.tp { 2 } else { 0 },
            tp_groups: usize::MAX,
            // §L13: trace a deterministic half of the property-test
            // workload so the span plumbing rides every swap/kill/shed
            // combination without asserting on timings.
            trace_sample: 0.5,
            trace_ring: 512,
            trace_window_ms: 100,
        };
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), options);

        let mut rxs = Vec::new();
        for i in 0..s.requests {
            let (tx, rx) = std::sync::mpsc::channel();
            let toks: Vec<i32> = (0..3 + (i % 20)).map(|j| 2 + (j as i32 % 50)).collect();
            let req = if s.shed && i % 3 == 2 {
                // Already-expired deadline: must come back as a shed.
                Request::with_deadline(toks, tx, Instant::now())
            } else {
                Request::new(toks, tx)
            };
            if server.sender.send(req).is_err() {
                return false;
            }
            rxs.push(rx);
            if i == s.requests / 2 {
                let swap = SimSwapSpec {
                    cost_mult: 0.9,
                    bad: if s.bad { BadVersionMode::WrongTokens } else { BadVersionMode::None },
                };
                server.deploy_start(EngineSpec::Sim(swap.apply(&spec)));
            }
        }

        // Exactly one terminal response per request...
        let deadline = Instant::now() + Duration::from_secs(30);
        for rx in &rxs {
            let left = deadline.saturating_duration_since(Instant::now());
            if rx.recv_timeout(left).is_err() {
                return false; // a request never got its terminal response
            }
        }
        // ...and the rollout itself terminates (promoted, rolled back,
        // or aborted — never wedged).
        while !server.deploy_status().terminal() {
            if Instant::now() > deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = match server.shutdown() {
            Ok(st) => st,
            Err(_) => return false,
        };
        // No request may receive a second terminal response.
        if rxs.iter().any(|rx| rx.try_recv().is_ok()) {
            return false;
        }
        // Completions + typed failures partition the admitted set.
        stats.requests + stats.failed == s.requests
    });
}
