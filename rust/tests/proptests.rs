//! Property-based tests over the coordinator's pure substrates
//! (tokenizer, span corruption, batcher, metrics, json) using the
//! in-repo mini property harness (`util::prop` — proptest is not
//! available in the offline image; see DESIGN.md §4).

use altup::data::span::{corrupt, SpanConfig};
use altup::data::tasks::{exact_match, f1_score, Task, TaskKind};
use altup::data::tokenizer::{Tokenizer, EOS, PAD};
use altup::util::json::Json;
use altup::util::prop::{forall, Gen, Pair, TokenSeq, UsizeIn};
use altup::util::rng::Rng;

const CASES: usize = 150;

fn tk() -> Tokenizer {
    Tokenizer::new(2048).unwrap()
}

/// Sequences of content tokens (valid span-corruption input).
fn content_seq(min_len: usize, max_len: usize) -> TokenSeq {
    TokenSeq { vocab: 1500, min_len, max_len }
}

fn to_tokens(tkz: &Tokenizer, words: &[u32]) -> Vec<i32> {
    words.iter().map(|&w| tkz.encode_word(w)).collect()
}

#[test]
fn prop_span_corruption_reconstructs_input() {
    let tkz = tk();
    forall(1, CASES, &Pair(content_seq(4, 160), UsizeIn(0, 1 << 30)), |(words, seed)| {
        let tokens = to_tokens(&tkz, words);
        let mut rng = Rng::new(*seed as u64);
        let ex = corrupt(&tokens, SpanConfig::default(), &tkz, &mut rng);
        // Parse spans out of the target and substitute back.
        let mut spans: Vec<(i32, Vec<i32>)> = Vec::new();
        for &t in tkz.until_eos(&ex.dec_targets) {
            if tkz.is_sentinel(t) {
                spans.push((t, Vec::new()));
            } else if let Some(last) = spans.last_mut() {
                last.1.push(t);
            } else {
                return false; // target must start with a sentinel
            }
        }
        let mut rebuilt = Vec::new();
        for &t in tkz.until_eos(&ex.enc) {
            if tkz.is_sentinel(t) {
                match spans.iter().find(|(s, _)| *s == t) {
                    Some((_, span)) => rebuilt.extend_from_slice(span),
                    None => return false,
                }
            } else {
                rebuilt.push(t);
            }
        }
        rebuilt == tokens
    });
}

#[test]
fn prop_span_corruption_targets_shifted() {
    let tkz = tk();
    forall(2, CASES, &Pair(content_seq(4, 120), UsizeIn(0, 1 << 30)), |(words, seed)| {
        let tokens = to_tokens(&tkz, words);
        let mut rng = Rng::new(*seed as u64);
        let ex = corrupt(&tokens, SpanConfig::default(), &tkz, &mut rng);
        ex.dec_input[0] == PAD
            && ex.dec_input[1..] == ex.dec_targets[..ex.dec_targets.len() - 1]
            && *ex.dec_targets.last().unwrap() == EOS
    });
}

#[test]
fn prop_tokenizer_roundtrip() {
    let tkz = tk();
    forall(3, CASES, &content_seq(1, 64), |words| {
        let ids = tkz.encode_doc(words);
        let back = tkz.content_of(&ids);
        back == *words
    });
}

#[test]
fn prop_tokenizer_specials_never_content() {
    let tkz = tk();
    forall(4, CASES, &UsizeIn(0, 40), |&id| {
        let id = id as i32;
        // ids below FIRST_CONTENT decode to None
        if id < altup::data::tokenizer::FIRST_CONTENT {
            tkz.decode_token(id).is_none()
        } else {
            tkz.decode_token(id).is_some()
        }
    });
}

#[test]
fn prop_f1_bounds_and_symmetry() {
    let gen = Pair(content_seq(1, 12), content_seq(1, 12));
    forall(5, CASES, &gen, |(a, b)| {
        let f = f1_score(a, b);
        let fr = f1_score(b, a);
        (0.0..=1.0).contains(&f) && (f - fr).abs() < 1e-12
    });
}

#[test]
fn prop_em_implies_f1_one() {
    forall(6, CASES, &content_seq(1, 12), |a| {
        exact_match(a, a) == 1.0 && (f1_score(a, a) - 1.0).abs() < 1e-12
    });
}

#[test]
fn prop_task_examples_fit_geometry() {
    // Every task example's decoder side fits dec_len after truncation
    // and keeps input/target alignment.
    let gen = Pair(UsizeIn(0, 3), UsizeIn(0, 5000));
    forall(7, CASES, &gen, |&(kind_idx, index)| {
        let kind = [TaskKind::Glue, TaskKind::SuperGlue, TaskKind::Squad, TaskKind::TriviaQa]
            [kind_idx];
        let task = Task::new(kind, 2048, 17);
        let ex = task.example(index as u64, 62);
        ex.dec_input.len() == ex.dec_targets.len()
            && ex.dec_input[0] == PAD
            && !ex.answer.is_empty()
    });
}

#[test]
fn prop_json_roundtrip_numbers() {
    forall(8, CASES, &UsizeIn(0, 1 << 31), |&n| {
        let src = format!("{{\"v\": {n}, \"a\": [{n}, -{n}]}}");
        let v = Json::parse(&src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        re.get("v").as_i64() == Some(n as i64) && re.get("a").idx(1).as_i64() == Some(-(n as i64))
    });
}

#[test]
fn prop_json_roundtrip_strings() {
    struct Ascii;
    impl Gen for Ascii {
        type Value = String;
        fn draw(&self, rng: &mut Rng) -> String {
            let len = rng.range(0, 24);
            (0..len)
                .map(|_| char::from_u32(rng.range(0x20, 0x7F) as u32).unwrap())
                .collect()
        }
        fn shrink(&self, v: &String) -> Vec<String> {
            if v.is_empty() {
                vec![]
            } else {
                vec![v[..v.len() / 2].to_string(), String::new()]
            }
        }
    }
    forall(9, CASES, &Ascii, |s| {
        let v = Json::Str(s.clone());
        Json::parse(&v.to_string()).map(|r| r.as_str() == Some(s.as_str())).unwrap_or(false)
    });
}

#[test]
fn prop_rng_range_in_bounds() {
    forall(10, CASES, &Pair(UsizeIn(0, 1000), UsizeIn(1, 1000)), |&(lo, span)| {
        let mut rng = Rng::new((lo * 31 + span) as u64);
        let v = rng.range(lo, lo + span);
        v >= lo && v < lo + span
    });
}

#[test]
fn prop_batch_geometry_invariant() {
    use altup::data::batcher::Batch;
    use altup::data::tasks::Example;
    let gen = Pair(UsizeIn(1, 8), Pair(UsizeIn(4, 64), UsizeIn(2, 32)));
    forall(11, 60, &gen, |&(b, (enc_len, dec_len))| {
        let task = Task::new(TaskKind::Glue, 2048, 3);
        let examples: Vec<Example> = (0..b).map(|i| task.example(i as u64, 60)).collect();
        let batch = Batch::from_examples(&examples, b, enc_len, dec_len);
        batch.enc_tokens.len() == b * enc_len
            && batch.dec_input.len() == b * dec_len
            && batch.dec_targets.len() == b * dec_len
            && batch.answers.len() == b
    });
}
