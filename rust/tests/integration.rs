//! Integration tests over the full runtime: artifacts -> PJRT -> train/
//! eval/decode. Requires `make artifacts` (skips gracefully otherwise).

use altup::coordinator::metrics::MetricsLog;
use altup::coordinator::trainer::{DataSource, TrainOptions, Trainer};
use altup::data::batcher::PretrainBatcher;
use altup::runtime::artifact::{artifacts_root, load_named};
use altup::runtime::client::Client;
use altup::runtime::session::Session;

fn have_artifacts() -> bool {
    artifacts_root().join("micro-altup/meta.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn train_loss_decreases_micro_altup() {
    require_artifacts!();
    let client = Client::cpu().unwrap();
    let artifact = load_named("micro-altup").unwrap();
    let cfg = artifact.config.clone();
    let session = Session::open(&client, artifact, 0).unwrap();
    let batcher =
        PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 1);
    let mut trainer = Trainer::new(session, DataSource::Pretrain(batcher), MetricsLog::in_memory());
    let opts = TrainOptions {
        steps: 20,
        warmup: 1000,
        log_every: 5,
        verbose: false,
        ..Default::default()
    };
    let (ema, sps) = trainer.run(&client, &opts).unwrap();
    let first = trainer.log.records.first().unwrap().values["loss"];
    assert!(ema < first, "loss did not decrease: first={first} ema={ema}");
    assert!(sps > 0.0);
}

#[test]
fn eval_and_decode_micro_baseline() {
    require_artifacts!();
    let client = Client::cpu().unwrap();
    let artifact = load_named("micro-baseline").unwrap();
    let cfg = artifact.config.clone();
    let mut session = Session::open_eval(&client, artifact, 0).unwrap();
    let mut batcher =
        PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 2);
    let batch = batcher.next_batch();
    let m = session.eval_step(&client, &batch).unwrap();
    assert!(m.ntok > 0.0);
    assert!(m.loss.is_finite());
    // decode produces the right geometry, in-vocab ids
    let rows = session.decode(&client, &batch.enc_tokens).unwrap();
    assert_eq!(rows.len(), cfg.batch_size);
    for r in &rows {
        assert_eq!(r.len(), cfg.dec_len);
        assert!(r.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab_size));
    }
}

#[test]
fn train_is_deterministic() {
    require_artifacts!();
    let client = Client::cpu().unwrap();
    let run = || {
        let artifact = load_named("micro-baseline").unwrap();
        let cfg = artifact.config.clone();
        let session = Session::open(&client, artifact, 7).unwrap();
        let batcher =
            PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 7);
        let mut trainer =
            Trainer::new(session, DataSource::Pretrain(batcher), MetricsLog::in_memory());
        let opts = TrainOptions { steps: 5, log_every: 1, verbose: false, ..Default::default() };
        trainer.run(&client, &opts).unwrap();
        trainer.log.series("loss")
    };
    assert_eq!(run(), run());
}

#[test]
fn pallas_artifact_matches_jnp_artifact() {
    // The pallas-kerneled model and the jnp model share identical math;
    // with identical init + data their first-step losses must agree.
    require_artifacts!();
    if !artifacts_root().join("micro-pallas-altup/meta.json").exists() {
        return;
    }
    let client = Client::cpu().unwrap();
    let loss_of = |name: &str| {
        let artifact = load_named(name).unwrap();
        let cfg = artifact.config.clone();
        let session = Session::open(&client, artifact, 3).unwrap();
        let mut batcher =
            PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 3);
        let batch = batcher.next_batch();
        let mut s = session;
        s.train_step(&client, 1e-3, 1, &batch).unwrap().loss
    };
    let l_jnp = loss_of("micro-altup");
    let l_pal = loss_of("micro-pallas-altup");
    assert!(
        (l_jnp - l_pal).abs() < 2e-3 * l_jnp.abs().max(1.0),
        "jnp={l_jnp} pallas={l_pal}"
    );
}

#[test]
fn checkpoint_resume_continues_exactly() {
    require_artifacts!();
    let client = Client::cpu().unwrap();
    let artifact = load_named("micro-baseline").unwrap();
    let cfg = artifact.config.clone();

    // Train 6 steps in one go.
    let mut s1 = Session::open(&client, artifact.clone(), 11).unwrap();
    let mut b1 = PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 11);
    let mut losses_a = Vec::new();
    for _ in 0..6 {
        let b = b1.next_batch();
        losses_a.push(s1.train_step(&client, 1e-2, s1.store.step as u32 + 1, &b).unwrap().loss);
    }

    // Train 3, checkpoint, reload, train 3 more.
    let mut s2 = Session::open(&client, artifact.clone(), 11).unwrap();
    let mut b2 = PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 11);
    let mut losses_b = Vec::new();
    for _ in 0..3 {
        let b = b2.next_batch();
        losses_b.push(s2.train_step(&client, 1e-2, s2.store.step as u32 + 1, &b).unwrap().loss);
    }
    let path = std::env::temp_dir().join(format!("altup-it-{}.ckpt", std::process::id()));
    s2.checkpoint(&path).unwrap();
    let mut s3 = Session::open(&client, artifact, 99).unwrap();
    s3.store = altup::runtime::params::ParamStore::load(&path, &s3.artifact).unwrap();
    std::fs::remove_file(&path).unwrap();
    for _ in 0..3 {
        let b = b2.next_batch();
        losses_b.push(s3.train_step(&client, 1e-2, s3.store.step as u32 + 1, &b).unwrap().loss);
    }
    for (a, b) in losses_a.iter().zip(losses_b.iter()) {
        assert!((a - b).abs() < 1e-5, "{losses_a:?} vs {losses_b:?}");
    }
}

/// §Perf L4 guard: the device-resident buffer cache must not go stale
/// across sync/checkpoint — train N steps under the device cache,
/// checkpoint, reload into a fresh session, and the eval metrics must
/// match an identical run with the cache fully disabled
/// (ALTUP_NO_STATE_CACHE semantics, set via the race-free API).
#[test]
fn device_cache_checkpoint_eval_parity_with_no_cache() {
    require_artifacts!();
    use altup::runtime::session::CacheMode;
    let client = Client::cpu().unwrap();

    let run = |mode: CacheMode, tag: &str| {
        let artifact = load_named("micro-altup").unwrap();
        let cfg = artifact.config.clone();
        let mut s = Session::open(&client, artifact, 13).unwrap();
        s.set_cache_mode(mode).unwrap();
        let mut b =
            PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 13);
        let mut losses = Vec::new();
        for _ in 0..4 {
            let batch = b.next_batch();
            losses.push(
                s.train_step(&client, 1e-2, s.store.step as u32 + 1, &batch).unwrap().loss,
            );
        }
        let path = std::env::temp_dir()
            .join(format!("altup-parity-{tag}-{}.ckpt", std::process::id()));
        s.checkpoint(&path).unwrap();

        // Reload into a fresh session (different init seed on purpose)
        // and evaluate: the checkpoint must fully determine the result.
        let mut s2 = Session::open_eval(&client, load_named("micro-altup").unwrap(), 999).unwrap();
        s2.set_cache_mode(mode).unwrap();
        s2.store =
            altup::runtime::params::ParamStore::load(&path, &s2.artifact).unwrap();
        s2.invalidate_state();
        std::fs::remove_file(&path).unwrap();
        let mut eb =
            PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 77);
        let batch = eb.next_batch();
        let m = s2.eval_step(&client, &batch).unwrap();
        (losses, m.loss, m.correct, m.ntok)
    };

    let (losses_dev, loss_dev, corr_dev, ntok_dev) = run(CacheMode::Device, "dev");
    let (losses_off, loss_off, corr_off, ntok_off) = run(CacheMode::Off, "off");
    for (a, b) in losses_dev.iter().zip(losses_off.iter()) {
        assert!((a - b).abs() < 1e-5, "train divergence: {losses_dev:?} vs {losses_off:?}");
    }
    assert!(
        (loss_dev - loss_off).abs() < 1e-5,
        "eval loss parity: device={loss_dev} off={loss_off}"
    );
    assert_eq!(corr_dev, corr_off, "eval correct parity");
    assert_eq!(ntok_dev, ntok_off, "eval ntok parity");
}

#[test]
fn param_count_meta_matches_store() {
    require_artifacts!();
    for name in ["micro-baseline", "micro-altup", "micro-recycled"] {
        let artifact = load_named(name).unwrap();
        let store = altup::runtime::params::ParamStore::init(&artifact, 0);
        assert_eq!(store.num_params(), artifact.param_count_total, "{name}");
    }
}
