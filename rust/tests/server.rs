//! Serving-stack integration tests that need no PJRT backend: the
//! multi-replica router, shape-bucketed batching, and the slot-based
//! continuous-batching scheduler run against the deterministic sim
//! engine, so scheduling, bucket/split parity, EOS early-exit, stats
//! merging, and failure modes are exercised in every build.

use altup::coordinator::server::{
    EngineSpec, Request, ServerHandle, ServerOptions, ServerStats, SimSpec,
};
use altup::data::tokenizer::EOS;
use altup::runtime::session::{bucket_for, bucket_lengths};
use std::time::Duration;

fn sim_spec() -> SimSpec {
    // Zero cost knobs keep the scheduler tests fast; throughput
    // behavior is covered by benches/server_throughput.rs.
    SimSpec {
        batch_size: 4,
        enc_len: 64,
        dec_len: 8,
        vocab_size: 211,
        token_ns: 0,
        dtoken_ns: 0,
        dstep_ns: 0,
        split_decode: true,
    }
}

/// Batch-level (run-to-completion) options — the §Perf L5 discipline.
fn opts(replicas: usize, bucketed: bool) -> ServerOptions {
    ServerOptions {
        batch_window: Duration::from_millis(2),
        seed: 0,
        checkpoint: None,
        replicas,
        bucketed,
        slots: 0,
        continuous: false,
        queue_cap: 1024,
    }
}

/// Continuous-batching options (§Perf L6).
fn copts(replicas: usize, slots: usize) -> ServerOptions {
    ServerOptions { continuous: true, slots, ..opts(replicas, true) }
}

fn prompt(len: usize) -> Vec<i32> {
    (0..len).map(|i| (i % 200) as i32 + 2).collect()
}

fn collect(server: &ServerHandle, lens: &[usize]) -> Vec<Vec<i32>> {
    lens.iter().map(|&l| server.infer(prompt(l)).unwrap().tokens).collect()
}

/// Decode the same prompts through bucketed serving and through
/// always-full-length serving: output tokens must be identical no
/// matter which bucket executed them.
#[test]
fn bucket_vs_full_length_decode_parity() {
    let lens = [1usize, 3, 8, 9, 15, 16, 17, 31, 32, 40, 63, 64, 80];
    let run = |bucketed: bool| -> Vec<Vec<i32>> {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(sim_spec()), opts(1, bucketed));
        let out = collect(&server, &lens);
        server.shutdown().unwrap();
        out
    };
    let bucketed = run(true);
    let full = run(false);
    assert_eq!(bucketed, full, "tokens must not depend on the executed bucket");
}

/// The §Perf L6 acceptance contract: the split prefill + decode_token
/// path produces exactly the rows the monolithic decode_step path
/// produces, while actually early-exiting at EOS (fewer decode tokens
/// executed) and reporting the new scheduler metrics.
#[test]
fn continuous_vs_batch_decode_parity_and_early_exit() {
    let lens = [1usize, 3, 5, 8, 9, 15, 17, 21, 31, 33, 40, 63, 64, 80];
    let run = |options: ServerOptions| -> (Vec<Vec<i32>>, ServerStats) {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(sim_spec()), options);
        let out = collect(&server, &lens);
        (out, server.shutdown().unwrap())
    };
    let (cont_rows, cont) = run(copts(1, 4));
    let (batch_rows, batch) = run(opts(1, true));
    assert_eq!(cont_rows, batch_rows, "split and monolithic paths must emit identical rows");
    for row in &cont_rows {
        assert_eq!(*row.last().unwrap(), EOS, "every sim row ends at its EOS");
        assert!(row.len() <= sim_spec().dec_len);
    }
    assert_eq!(cont.requests, lens.len());
    assert_eq!(batch.requests, lens.len());
    assert_eq!(cont.tokens_generated, batch.tokens_generated, "same tokens delivered");

    // The continuous path actually scheduled at token granularity...
    assert!(cont.decode_steps > 0, "fused decode iterations recorded");
    assert!(cont.prefills > 0, "prefill groups recorded");
    assert!(cont.occupancy.steps() as usize == cont.decode_steps);
    assert!(cont.occupancy.mean() > 0.0 && cont.occupancy.mean() <= 4.0);
    // ...and stopped paying for retired rows (EOS-sampled lengths make
    // at least some rows shorter than dec_len).
    assert!(cont.tokens_saved > 0, "early exit must save decode tokens");
    assert!(cont.early_exit_ratio() > 0.0 && cont.early_exit_ratio() < 1.0);

    // The batch-level path ran no fused iterations and saved nothing.
    assert_eq!(batch.decode_steps, 0);
    assert_eq!(batch.prefills, 0);
    assert_eq!(batch.tokens_saved, 0);
    // Per-token latency is recorded per request on both paths.
    assert_eq!(cont.token_latency.count() as usize, lens.len());
    assert_eq!(batch.token_latency.count() as usize, lens.len());
}

/// An engine without the split HLO pair must fall back cleanly to the
/// batch-level loop even when continuous scheduling is requested —
/// same outputs, no fused-step metrics.
#[test]
fn continuous_falls_back_without_split_hlo() {
    let lens = [2usize, 9, 17, 40, 64];
    let split = sim_spec();
    let unsplit = SimSpec { split_decode: false, ..sim_spec() };
    let run = |spec: SimSpec| -> (Vec<Vec<i32>>, ServerStats) {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), copts(1, 4));
        let out = collect(&server, &lens);
        (out, server.shutdown().unwrap())
    };
    let (rows_split, stats_split) = run(split);
    let (rows_fallback, stats_fallback) = run(unsplit);
    assert_eq!(rows_split, rows_fallback, "fallback must not change outputs");
    assert!(stats_split.decode_steps > 0);
    assert_eq!(stats_fallback.decode_steps, 0, "fallback ran the monolithic loop");
    assert_eq!(stats_fallback.prefills, 0);
    assert_eq!(stats_fallback.tokens_saved, 0);
    assert_eq!(stats_fallback.requests, lens.len());
}

#[test]
fn bucketed_serving_reduces_executed_tokens() {
    let spec = sim_spec();
    let lens = [4usize, 5, 6, 7, 20, 21, 40, 64];
    let run = |bucketed: bool| {
        let server =
            ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), opts(1, bucketed));
        for &l in &lens {
            let r = server.infer(prompt(l)).unwrap();
            assert!(!r.truncated);
            if bucketed {
                assert_eq!(r.bucket, bucket_for(l, spec.enc_len), "len {l}");
            } else {
                assert_eq!(r.bucket, spec.enc_len);
            }
        }
        server.shutdown().unwrap()
    };
    let b = run(true);
    let f = run(false);
    assert_eq!(b.requests, lens.len());
    assert_eq!(f.requests, lens.len());
    assert_eq!(b.prompt_tokens, f.prompt_tokens);
    assert!(
        b.executed_tokens < f.executed_tokens,
        "bucketed {} vs full {}",
        b.executed_tokens,
        f.executed_tokens
    );
    assert!(b.waste_ratio() < f.waste_ratio());
}

#[test]
fn over_length_prompts_still_flagged_truncated() {
    let spec = sim_spec();
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), opts(1, true));
    let r = server.infer(prompt(spec.enc_len + 13)).unwrap();
    assert!(r.truncated, "over-enc_len prompt must be flagged");
    assert_eq!(r.bucket, spec.enc_len, "truncated prompts run the full bucket");
    let ok = server.infer(prompt(spec.enc_len)).unwrap();
    assert!(!ok.truncated);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.truncated, 1);
}

/// N replicas must produce exactly the same tokens as 1 replica for the
/// same prompts (determinism), and shutdown must merge every replica's
/// counters (sample count == request count, fills sum up). Runs the
/// continuous scheduler — the default serving discipline.
#[test]
fn multi_replica_determinism_and_stats_merge() {
    let spec = sim_spec();
    let prompts: Vec<Vec<i32>> = (0..32).map(|i| prompt(1 + (i * 7) % 70)).collect();

    let run = |replicas: usize| -> (Vec<Vec<i32>>, ServerStats) {
        let server =
            ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), copts(replicas, 4));
        // Submit from 4 concurrent client threads to exercise batching
        // across replicas, then collect in a stable order.
        let mut joins = Vec::new();
        for c in 0..4 {
            let sender = server.sender.clone();
            let mine: Vec<(usize, Vec<i32>)> = prompts
                .iter()
                .cloned()
                .enumerate()
                .skip(c)
                .step_by(4)
                .collect();
            joins.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                for (idx, p) in mine {
                    let (tx, rx) = std::sync::mpsc::channel();
                    sender.send(Request::new(p, tx)).unwrap();
                    out.push((idx, rx.recv().unwrap()));
                }
                out
            }));
        }
        let mut responses: Vec<Option<Vec<i32>>> = vec![None; prompts.len()];
        let mut max_replica = 0usize;
        for j in joins {
            for (idx, resp) in j.join().unwrap() {
                max_replica = max_replica.max(resp.replica);
                responses[idx] = Some(resp.tokens);
            }
        }
        assert!(max_replica < replicas.max(1));
        let stats = server.shutdown().unwrap();
        (responses.into_iter().map(|r| r.unwrap()).collect(), stats)
    };

    let (tokens_one, stats_one) = run(1);
    let (tokens_three, stats_three) = run(3);
    assert_eq!(tokens_one, tokens_three, "replica count must not change outputs");

    for stats in [&stats_one, &stats_three] {
        assert_eq!(stats.requests, prompts.len());
        assert_eq!(stats.total_fill, prompts.len(), "fills sum to total requests");
        assert_eq!(
            stats.latency_count() as usize,
            prompts.len(),
            "one latency sample per request"
        );
        assert!(stats.batches >= 1 && stats.batches <= prompts.len());
        assert!(stats.p95_ms() >= stats.p50_ms());
        assert!(stats.executed_tokens >= stats.prompt_tokens);
        assert!(stats.decode_steps > 0, "continuous path exercised");
    }
    assert_eq!(stats_one.replicas, 1);
    assert_eq!(stats_three.replicas, 3);
}

/// A dead model thread must surface as an error from `infer`, not a
/// hang: spawning against a nonexistent artifact kills router+replicas
/// at startup.
#[test]
fn infer_errors_when_model_thread_dead() {
    let server = ServerHandle::spawn(
        "definitely-not-an-artifact",
        ServerOptions { batch_window: Duration::from_millis(1), ..Default::default() },
    );
    let err = server.infer(vec![1, 2, 3]);
    assert!(err.is_err(), "infer against a dead server must error, not hang");
    assert!(server.shutdown().is_err(), "shutdown reports the startup failure");
}

#[test]
fn bucket_ladder_is_monotone_per_request() {
    // Response buckets from a bucketed server always come off the
    // ladder and always fit the prompt.
    let spec = sim_spec();
    let ladder = bucket_lengths(spec.enc_len);
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), copts(2, 4));
    for len in [1usize, 7, 8, 9, 30, 33, 64, 100] {
        let r = server.infer(prompt(len)).unwrap();
        assert!(ladder.contains(&r.bucket), "bucket {} for len {len}", r.bucket);
        assert!(r.bucket >= len.min(spec.enc_len));
        assert!(!r.tokens.is_empty() && r.tokens.len() <= spec.dec_len);
        assert_eq!(*r.tokens.last().unwrap(), EOS);
    }
    server.shutdown().unwrap();
}

/// Satellite: reported latency must include time a backpressured
/// request spends blocked in the bounded request channel. With
/// batch_size=1, one replica, a 1-deep request channel, and a ~20 ms
/// decode, six concurrent requests serialize over ~120 ms; most of a
/// late request's life is spent blocked in `send`. Because the latency
/// clock starts at `Request::new` (before the blocking send), the
/// slowest observed latency must reflect several decode rounds — if
/// the clock started at router admission it would only ever see
/// roughly one round's worth.
#[test]
fn backpressured_infer_latency_includes_queue_time() {
    let spec = SimSpec {
        batch_size: 1,
        enc_len: 16,
        dec_len: 4,
        vocab_size: 211,
        token_ns: 0,
        dtoken_ns: 0,
        dstep_ns: 5_000_000, // 4 steps x 5 ms = 20 ms per monolithic batch
        split_decode: false,
    };
    let options = ServerOptions {
        batch_window: Duration::from_millis(0),
        queue_cap: 1,
        ..opts(1, true)
    };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
    let n = 6;
    let mut joins = Vec::new();
    for i in 0..n {
        let sender = server.sender.clone();
        joins.push(std::thread::spawn(move || {
            let (tx, rx) = std::sync::mpsc::channel();
            sender.send(Request::new(prompt(4 + i), tx)).unwrap();
            rx.recv().unwrap().latency
        }));
    }
    let latencies: Vec<Duration> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.latency_count() as usize, n);
    let max = latencies.iter().max().unwrap();
    assert!(
        *max >= Duration::from_millis(50),
        "queueing time missing from latency: max {max:?} over {latencies:?}"
    );
}

/// Continuous scheduling keeps admitting while slots decode: with slow
/// per-step decode and fast prefill, a server with more slots than
/// batch_size reaches occupancy above one batch's fill.
#[test]
fn continuous_scheduler_overlaps_admission_and_decode() {
    let spec = SimSpec {
        batch_size: 2,
        enc_len: 32,
        dec_len: 16,
        vocab_size: 211,
        token_ns: 0,
        dtoken_ns: 50_000,
        dstep_ns: 200_000,
        split_decode: true,
    };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), copts(1, 6));
    let mut joins = Vec::new();
    for i in 0..18 {
        let sender = server.sender.clone();
        joins.push(std::thread::spawn(move || {
            let (tx, rx) = std::sync::mpsc::channel();
            sender.send(Request::new(prompt(3 + (i * 5) % 28), tx)).unwrap();
            rx.recv().unwrap()
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 18);
    assert!(stats.decode_steps > 0);
    assert!(
        stats.occupancy.mean() > 1.0,
        "slots should host multiple concurrent requests: {:.2}",
        stats.occupancy.mean()
    );
    assert!(stats.occupancy.mean() <= 6.0);
}
