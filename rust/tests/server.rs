//! Serving-stack integration tests that need no PJRT backend: the
//! multi-replica router + shape-bucketed batching run against the
//! deterministic sim engine, so scheduling, bucket parity, stats
//! merging, and failure modes are exercised in every build. A
//! real-artifact parity test rides along and skips gracefully when
//! `make artifacts` hasn't run (or the backend cannot execute HLO).

use altup::coordinator::server::{
    EngineSpec, Request, ServerHandle, ServerOptions, SimSpec,
};
use altup::runtime::session::{bucket_for, bucket_lengths};
use std::time::Duration;

fn sim_spec() -> SimSpec {
    // token_ns=0 keeps the scheduler tests fast; throughput behavior is
    // covered by benches/server_throughput.rs.
    SimSpec { batch_size: 4, enc_len: 64, dec_len: 8, vocab_size: 211, token_ns: 0 }
}

fn opts(replicas: usize, bucketed: bool) -> ServerOptions {
    ServerOptions {
        batch_window: Duration::from_millis(2),
        replicas,
        bucketed,
        ..Default::default()
    }
}

fn prompt(len: usize) -> Vec<i32> {
    (0..len).map(|i| (i % 200) as i32 + 1).collect()
}

/// Decode the same prompts through bucketed serving and through
/// always-full-length serving: output tokens must be identical no
/// matter which bucket executed them.
#[test]
fn bucket_vs_full_length_decode_parity() {
    let lens = [1usize, 3, 8, 9, 15, 16, 17, 31, 32, 40, 63, 64, 80];
    let run = |bucketed: bool| -> Vec<Vec<i32>> {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(sim_spec()), opts(1, bucketed));
        let out: Vec<Vec<i32>> =
            lens.iter().map(|&l| server.infer(prompt(l)).unwrap().tokens).collect();
        server.shutdown().unwrap();
        out
    };
    let bucketed = run(true);
    let full = run(false);
    assert_eq!(bucketed, full, "tokens must not depend on the executed bucket");
}

#[test]
fn bucketed_serving_reduces_executed_tokens() {
    let spec = sim_spec();
    let lens = [4usize, 5, 6, 7, 20, 21, 40, 64];
    let run = |bucketed: bool| {
        let server =
            ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), opts(1, bucketed));
        for &l in &lens {
            let r = server.infer(prompt(l)).unwrap();
            assert!(!r.truncated);
            if bucketed {
                assert_eq!(r.bucket, bucket_for(l, spec.enc_len), "len {l}");
            } else {
                assert_eq!(r.bucket, spec.enc_len);
            }
        }
        server.shutdown().unwrap()
    };
    let b = run(true);
    let f = run(false);
    assert_eq!(b.requests, lens.len());
    assert_eq!(f.requests, lens.len());
    assert_eq!(b.prompt_tokens, f.prompt_tokens);
    assert!(
        b.executed_tokens < f.executed_tokens,
        "bucketed {} vs full {}",
        b.executed_tokens,
        f.executed_tokens
    );
    assert!(b.waste_ratio() < f.waste_ratio());
}

#[test]
fn over_length_prompts_still_flagged_truncated() {
    let spec = sim_spec();
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), opts(1, true));
    let r = server.infer(prompt(spec.enc_len + 13)).unwrap();
    assert!(r.truncated, "over-enc_len prompt must be flagged");
    assert_eq!(r.bucket, spec.enc_len, "truncated prompts run the full bucket");
    let ok = server.infer(prompt(spec.enc_len)).unwrap();
    assert!(!ok.truncated);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.truncated, 1);
}

/// N replicas must produce exactly the same tokens as 1 replica for the
/// same prompts (determinism), and shutdown must merge every replica's
/// counters (sample count == request count, fills sum up).
#[test]
fn multi_replica_determinism_and_stats_merge() {
    let spec = sim_spec();
    let prompts: Vec<Vec<i32>> = (0..32).map(|i| prompt(1 + (i * 7) % 70)).collect();

    let run = |replicas: usize| -> (Vec<Vec<i32>>, altup::coordinator::server::ServerStats) {
        let server =
            ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), opts(replicas, true));
        // Submit from 4 concurrent client threads to exercise batching
        // across replicas, then collect in a stable order.
        let mut joins = Vec::new();
        for c in 0..4 {
            let sender = server.sender.clone();
            let mine: Vec<(usize, Vec<i32>)> = prompts
                .iter()
                .cloned()
                .enumerate()
                .skip(c)
                .step_by(4)
                .collect();
            joins.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                for (idx, p) in mine {
                    let (tx, rx) = std::sync::mpsc::channel();
                    sender.send(Request::new(p, tx)).unwrap();
                    out.push((idx, rx.recv().unwrap()));
                }
                out
            }));
        }
        let mut responses: Vec<Option<Vec<i32>>> = vec![None; prompts.len()];
        let mut max_replica = 0usize;
        for j in joins {
            for (idx, resp) in j.join().unwrap() {
                max_replica = max_replica.max(resp.replica);
                responses[idx] = Some(resp.tokens);
            }
        }
        assert!(max_replica < replicas.max(1));
        let stats = server.shutdown().unwrap();
        (responses.into_iter().map(|r| r.unwrap()).collect(), stats)
    };

    let (tokens_one, stats_one) = run(1);
    let (tokens_three, stats_three) = run(3);
    assert_eq!(tokens_one, tokens_three, "replica count must not change outputs");

    for stats in [&stats_one, &stats_three] {
        assert_eq!(stats.requests, prompts.len());
        assert_eq!(stats.total_fill, prompts.len(), "fills sum to total requests");
        assert_eq!(
            stats.latency_count() as usize,
            prompts.len(),
            "one latency sample per request"
        );
        assert!(stats.batches >= 1 && stats.batches <= prompts.len());
        assert!(stats.p95_ms() >= stats.p50_ms());
        assert!(stats.executed_tokens >= stats.prompt_tokens);
    }
    assert_eq!(stats_one.replicas, 1);
    assert_eq!(stats_three.replicas, 3);
}

/// A dead model thread must surface as an error from `infer`, not a
/// hang: spawning against a nonexistent artifact kills router+replicas
/// at startup.
#[test]
fn infer_errors_when_model_thread_dead() {
    let server = ServerHandle::spawn(
        "definitely-not-an-artifact",
        ServerOptions { batch_window: Duration::from_millis(1), ..Default::default() },
    );
    let err = server.infer(vec![1, 2, 3]);
    assert!(err.is_err(), "infer against a dead server must error, not hang");
    assert!(server.shutdown().is_err(), "shutdown reports the startup failure");
}

#[test]
fn bucket_ladder_is_monotone_per_request() {
    // Response buckets from a bucketed server always come off the
    // ladder and always fit the prompt.
    let spec = sim_spec();
    let ladder = bucket_lengths(spec.enc_len);
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), opts(2, true));
    for len in [1usize, 7, 8, 9, 30, 33, 64, 100] {
        let r = server.infer(prompt(len)).unwrap();
        assert!(ladder.contains(&r.bucket), "bucket {} for len {len}", r.bucket);
        assert!(r.bucket >= len.min(spec.enc_len));
        assert_eq!(r.tokens.len(), spec.dec_len);
    }
    server.shutdown().unwrap();
}
