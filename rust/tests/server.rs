//! Serving-stack integration tests that need no PJRT backend: the
//! multi-replica router, shape-bucketed batching, the slot-based
//! continuous-batching scheduler, and the §L7 fault-tolerant lifecycle
//! (replica supervision, request deadlines, graceful drain) run
//! against the deterministic sim engine, so scheduling, bucket/split
//! parity, EOS early-exit, stats merging, crash recovery, shedding,
//! and drain are exercised in every build.

use altup::coordinator::admission::parse_tenant_spec;
use altup::coordinator::deploy::{DeployOptions, DeployStatus};
use altup::coordinator::server::{
    BadVersionMode, CollectiveSpec, EngineSpec, FailReason, Request, Response, ServerHandle,
    ServerOptions, ServerStats, SimPoolSpec, SimSpec, SimSwapSpec, ROUTER_ID,
};
use altup::coordinator::trace::{self, Phase};
use altup::data::tokenizer::EOS;
use altup::runtime::session::{bucket_for, bucket_lengths};
use std::time::{Duration, Instant};

fn sim_spec() -> SimSpec {
    // Zero cost knobs keep the scheduler tests fast; throughput
    // behavior is covered by benches/server_throughput.rs.
    let mut spec = SimSpec::new(4, 64, 8);
    spec.vocab_size = 211;
    spec.token_ns = 0;
    spec.dtoken_ns = 0;
    spec.dstep_ns = 0;
    if let Some(d) = spec.draft.as_mut() {
        d.dtoken_ns = 0;
        d.dstep_ns = 0;
    }
    // Hermetic: `SimSpec::new` reads `ALTUP_POOL_PAGES` from the
    // environment; tests opt into paging via `paged_spec` only.
    spec.pool = None;
    spec
}

/// §L9 paged variant of `sim_spec`: same model geometry, decode state
/// served out of a `pool_pages`-page pool with `page_size`-token pages.
fn paged_spec(page_size: usize, pool_pages: usize, prefix_cache: bool) -> SimSpec {
    SimSpec {
        pool: Some(SimPoolSpec { page_size, pool_pages, prefix_cache }),
        ..sim_spec()
    }
}

/// Batch-level (run-to-completion) options — the §Perf L5 discipline.
fn opts(replicas: usize, bucketed: bool) -> ServerOptions {
    ServerOptions {
        batch_window: Duration::from_millis(2),
        seed: 0,
        checkpoint: None,
        replicas,
        bucketed,
        slots: 0,
        continuous: false,
        queue_cap: 1024,
        request_timeout_ms: None,
        max_retries: 2,
        replica_restarts: 2,
        spec_gamma: 0,
        tenants: Vec::new(),
        autoscale: 0,
        // 1 ms base backoff keeps the recovery tests as fast as the
        // pre-backoff spawn-on-crash behavior; the backoff test below
        // raises it explicitly.
        restart_backoff_ms: 1,
        // Hermetic §L11 deploy gates (`DeployOptions::default()` reads
        // ALTUP_DEPLOY_*): a short probation sized for test traffic,
        // and an idle-promotion clock fast enough that rollouts on an
        // idle fleet finish in tens of milliseconds.
        deploy: deploy_opts(),
        // §L12: whole-model fleet by default (env-free so an exported
        // ALTUP_TP cannot shard these tests); the TP tests below opt
        // in through `topts`.
        tp: 0,
        tp_groups: usize::MAX,
        // §L13: tracing off by default (env-free so an exported
        // ALTUP_TRACE_SAMPLE cannot perturb scheduler tests); the
        // trace tests below opt in through `tropts`.
        trace_sample: 0.0,
        trace_ring: 4096,
        trace_window_ms: 100,
    }
}

/// §L13 tracing options: continuous batching with every request traced
/// (sample 1.0) unless a test overrides the sampler.
fn tropts(replicas: usize, slots: usize, sample: f64) -> ServerOptions {
    ServerOptions { trace_sample: sample, ..copts(replicas, slots) }
}

/// §L11 deploy gates for tests: explicit (env-free) and fast.
fn deploy_opts() -> DeployOptions {
    DeployOptions {
        probation: 4,
        probation_ms: 150,
        probes: 2,
        max_err: 0.4,
        lat_factor: 100.0,
        hold_ms: 4000,
    }
}

/// Continuous-batching options (§Perf L6).
fn copts(replicas: usize, slots: usize) -> ServerOptions {
    ServerOptions { continuous: true, slots, ..opts(replicas, true) }
}

/// Speculative-decoding options (§L8) on top of continuous batching.
fn sopts(replicas: usize, slots: usize, gamma: usize) -> ServerOptions {
    ServerOptions { spec_gamma: gamma, ..copts(replicas, slots) }
}

fn prompt(len: usize) -> Vec<i32> {
    (0..len).map(|i| (i % 200) as i32 + 2).collect()
}

fn collect(server: &ServerHandle, lens: &[usize]) -> Vec<Vec<i32>> {
    lens.iter().map(|&l| server.infer(prompt(l)).unwrap().tokens).collect()
}

/// Fire `prompts` from `clients` concurrent threads through raw reply
/// channels and return every terminal `Response`, in submission order.
/// Panics if any reply channel is dropped without a terminal response
/// — the §L7 guarantee under test in the fault scenarios.
fn drive_concurrent(
    server: &ServerHandle,
    prompts: &[Vec<i32>],
    clients: usize,
) -> Vec<Response> {
    let mut joins = Vec::new();
    for c in 0..clients {
        let sender = server.sender.clone();
        let mine: Vec<(usize, Vec<i32>)> =
            prompts.iter().cloned().enumerate().skip(c).step_by(clients).collect();
        joins.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for (idx, p) in mine {
                let (tx, rx) = std::sync::mpsc::channel();
                sender.send(Request::new(p, tx)).expect("router accepts");
                out.push((idx, rx.recv().expect("terminal response (never a dropped channel)")));
            }
            out
        }));
    }
    let mut responses: Vec<Option<Response>> = (0..prompts.len()).map(|_| None).collect();
    for j in joins {
        for (idx, resp) in j.join().expect("client thread") {
            responses[idx] = Some(resp);
        }
    }
    responses.into_iter().map(|r| r.expect("every prompt answered")).collect()
}

/// Decode the same prompts through bucketed serving and through
/// always-full-length serving: output tokens must be identical no
/// matter which bucket executed them.
#[test]
fn bucket_vs_full_length_decode_parity() {
    let lens = [1usize, 3, 8, 9, 15, 16, 17, 31, 32, 40, 63, 64, 80];
    let run = |bucketed: bool| -> Vec<Vec<i32>> {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(sim_spec()), opts(1, bucketed));
        let out = collect(&server, &lens);
        server.shutdown().unwrap();
        out
    };
    let bucketed = run(true);
    let full = run(false);
    assert_eq!(bucketed, full, "tokens must not depend on the executed bucket");
}

/// The §Perf L6 acceptance contract: the split prefill + decode_token
/// path produces exactly the rows the monolithic decode_step path
/// produces, while actually early-exiting at EOS (fewer decode tokens
/// executed) and reporting the new scheduler metrics.
#[test]
fn continuous_vs_batch_decode_parity_and_early_exit() {
    let lens = [1usize, 3, 5, 8, 9, 15, 17, 21, 31, 33, 40, 63, 64, 80];
    let run = |options: ServerOptions| -> (Vec<Vec<i32>>, ServerStats) {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(sim_spec()), options);
        let out = collect(&server, &lens);
        (out, server.shutdown().unwrap())
    };
    let (cont_rows, cont) = run(copts(1, 4));
    let (batch_rows, batch) = run(opts(1, true));
    assert_eq!(cont_rows, batch_rows, "split and monolithic paths must emit identical rows");
    for row in &cont_rows {
        assert_eq!(*row.last().unwrap(), EOS, "every sim row ends at its EOS");
        assert!(row.len() <= sim_spec().dec_len);
    }
    assert_eq!(cont.requests, lens.len());
    assert_eq!(batch.requests, lens.len());
    assert_eq!(cont.tokens_generated, batch.tokens_generated, "same tokens delivered");

    // The continuous path actually scheduled at token granularity...
    assert!(cont.decode_steps > 0, "fused decode iterations recorded");
    assert!(cont.prefills > 0, "prefill groups recorded");
    assert!(cont.occupancy.steps() as usize == cont.decode_steps);
    assert!(cont.occupancy.mean() > 0.0 && cont.occupancy.mean() <= 4.0);
    // ...and stopped paying for retired rows (EOS-sampled lengths make
    // at least some rows shorter than dec_len).
    assert!(cont.tokens_saved > 0, "early exit must save decode tokens");
    assert!(cont.early_exit_ratio() > 0.0 && cont.early_exit_ratio() < 1.0);

    // The batch-level path ran no fused iterations and saved nothing.
    assert_eq!(batch.decode_steps, 0);
    assert_eq!(batch.prefills, 0);
    assert_eq!(batch.tokens_saved, 0);
    // Per-token latency is recorded per request on both paths.
    assert_eq!(cont.token_latency.count() as usize, lens.len());
    assert_eq!(batch.token_latency.count() as usize, lens.len());
    // Healthy runs report no fault activity.
    for stats in [&cont, &batch] {
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.restarts, 0);
        assert_eq!(stats.sheds, 0);
    }
}

/// Satellite: EOS edge cases on both decode paths. A prompt whose
/// hash-sampled generation length is 1 emits EOS as its very first
/// token; an injected stuck generation never emits EOS within
/// `dec_len`. Both must produce identical `Response.tokens` under
/// batch-level and continuous serving. (prompt(46) samples gen_len 1;
/// prompt(3)'s hash lands in the stuck_every=3 class — pinned by the
/// structural asserts below, not by magic knowledge.)
#[test]
fn eos_first_token_and_no_eos_parity_across_decode_paths() {
    let mut spec = sim_spec();
    spec.fault.stuck_every = 3;
    let lens = [1usize, 2, 3, 9, 17, 46, 64];
    let run = |options: ServerOptions| -> Vec<Vec<i32>> {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), options);
        let out = collect(&server, &lens);
        server.shutdown().unwrap();
        out
    };
    let cont = run(copts(1, 4));
    let batch = run(opts(1, true));
    assert_eq!(cont, batch, "EOS edge cases must not split the decode paths");

    // EOS as the very first emitted token: the row is exactly [EOS].
    let eos_first: Vec<&Vec<i32>> =
        cont.iter().filter(|r| r.len() == 1 && r[0] == EOS).collect();
    assert!(!eos_first.is_empty(), "workload must include a gen_len==1 prompt: {cont:?}");

    // No EOS within dec_len: full-length row, EOS-free.
    let dec_len = spec.dec_len;
    let no_eos: Vec<&Vec<i32>> =
        cont.iter().filter(|r| r.len() == dec_len && !r.contains(&EOS)).collect();
    assert!(!no_eos.is_empty(), "workload must include a stuck (no-EOS) prompt: {cont:?}");

    // Everything else still terminates at EOS within dec_len.
    for row in &cont {
        assert!(row.len() <= dec_len);
        assert!(row.contains(&EOS) || row.len() == dec_len);
    }
}

/// An engine without the split HLO pair must fall back cleanly to the
/// batch-level loop even when continuous scheduling is requested —
/// same outputs, no fused-step metrics.
#[test]
fn continuous_falls_back_without_split_hlo() {
    let lens = [2usize, 9, 17, 40, 64];
    let split = sim_spec();
    let unsplit = SimSpec { split_decode: false, ..sim_spec() };
    let run = |spec: SimSpec| -> (Vec<Vec<i32>>, ServerStats) {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), copts(1, 4));
        let out = collect(&server, &lens);
        (out, server.shutdown().unwrap())
    };
    let (rows_split, stats_split) = run(split);
    let (rows_fallback, stats_fallback) = run(unsplit);
    assert_eq!(rows_split, rows_fallback, "fallback must not change outputs");
    assert!(stats_split.decode_steps > 0);
    assert_eq!(stats_fallback.decode_steps, 0, "fallback ran the monolithic loop");
    assert_eq!(stats_fallback.prefills, 0);
    assert_eq!(stats_fallback.tokens_saved, 0);
    assert_eq!(stats_fallback.requests, lens.len());
}

#[test]
fn bucketed_serving_reduces_executed_tokens() {
    let spec = sim_spec();
    let lens = [4usize, 5, 6, 7, 20, 21, 40, 64];
    let run = |bucketed: bool| {
        let server =
            ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), opts(1, bucketed));
        for &l in &lens {
            let r = server.infer(prompt(l)).unwrap();
            assert!(!r.truncated);
            if bucketed {
                assert_eq!(r.bucket, bucket_for(l, spec.enc_len), "len {l}");
            } else {
                assert_eq!(r.bucket, spec.enc_len);
            }
        }
        server.shutdown().unwrap()
    };
    let b = run(true);
    let f = run(false);
    assert_eq!(b.requests, lens.len());
    assert_eq!(f.requests, lens.len());
    assert_eq!(b.prompt_tokens, f.prompt_tokens);
    assert!(
        b.executed_tokens < f.executed_tokens,
        "bucketed {} vs full {}",
        b.executed_tokens,
        f.executed_tokens
    );
    assert!(b.waste_ratio() < f.waste_ratio());
}

#[test]
fn over_length_prompts_still_flagged_truncated() {
    let spec = sim_spec();
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), opts(1, true));
    let r = server.infer(prompt(spec.enc_len + 13)).unwrap();
    assert!(r.truncated, "over-enc_len prompt must be flagged");
    assert_eq!(r.bucket, spec.enc_len, "truncated prompts run the full bucket");
    let ok = server.infer(prompt(spec.enc_len)).unwrap();
    assert!(!ok.truncated);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.truncated, 1);
}

/// N replicas must produce exactly the same tokens as 1 replica for the
/// same prompts (determinism), and shutdown must merge every replica's
/// counters (sample count == request count, fills sum up). Runs the
/// continuous scheduler — the default serving discipline.
#[test]
fn multi_replica_determinism_and_stats_merge() {
    let spec = sim_spec();
    let prompts: Vec<Vec<i32>> = (0..32).map(|i| prompt(1 + (i * 7) % 70)).collect();

    let run = |replicas: usize| -> (Vec<Vec<i32>>, ServerStats) {
        let server =
            ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), copts(replicas, 4));
        let responses = drive_concurrent(&server, &prompts, 4);
        let max_replica = responses.iter().map(|r| r.replica).max().unwrap();
        assert!(max_replica < replicas.max(1));
        let stats = server.shutdown().unwrap();
        (responses.into_iter().map(|r| r.tokens).collect(), stats)
    };

    let (tokens_one, stats_one) = run(1);
    let (tokens_three, stats_three) = run(3);
    assert_eq!(tokens_one, tokens_three, "replica count must not change outputs");

    for stats in [&stats_one, &stats_three] {
        assert_eq!(stats.requests, prompts.len());
        assert_eq!(stats.total_fill, prompts.len(), "fills sum to total requests");
        assert_eq!(
            stats.latency_count() as usize,
            prompts.len(),
            "one latency sample per request"
        );
        assert!(stats.batches >= 1 && stats.batches <= prompts.len());
        assert!(stats.p95_ms() >= stats.p50_ms());
        assert!(stats.executed_tokens >= stats.prompt_tokens);
        assert!(stats.decode_steps > 0, "continuous path exercised");
        assert_eq!(stats.failed, 0);
    }
    assert_eq!(stats_one.replicas, 1);
    assert_eq!(stats_three.replicas, 3);
}

/// §L7 tentpole, deterministic single-replica variant: the only
/// replica is killed mid-run; the supervisor must requeue its
/// in-flight requests to the respawned replacement, every request must
/// still succeed with exactly the healthy run's tokens, and shutdown
/// must report the recovery (1 restart, >=1 retry, 2 merged stat
/// sets) rather than an error.
#[test]
fn supervisor_recovers_killed_replica_and_requeues_in_flight() {
    let prompts: Vec<Vec<i32>> = (0..16).map(|i| prompt(2 + (i * 9) % 60)).collect();

    let healthy = {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(sim_spec()), copts(1, 4));
        let out = drive_concurrent(&server, &prompts, 4);
        server.shutdown().unwrap();
        out
    };

    let mut spec = sim_spec();
    // Kill the original replica (id 0) on its second engine call: the
    // first admission group has been prefilled, so its ledger is
    // provably non-empty when the panic fires.
    spec.fault.kill_replica = Some(0);
    spec.fault.kill_after_calls = 2;
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), copts(1, 4));
    let responses = drive_concurrent(&server, &prompts, 4);
    let stats = server.shutdown().expect("recovered server shuts down cleanly");

    for (resp, healthy_tokens) in responses.iter().zip(healthy.iter()) {
        assert!(
            resp.failure.is_none(),
            "one crash within the retry budget must not fail requests: {:?}",
            resp.failure
        );
        assert_eq!(&resp.tokens, &healthy_tokens.tokens, "retried decode is deterministic");
    }
    assert_eq!(stats.requests, prompts.len());
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.restarts, 1, "exactly one replacement spawned");
    assert!(stats.retries >= 1, "the killed replica's in-flight work was requeued");
    assert_eq!(stats.replicas, 2, "crashed incarnation + replacement both merged");
}

/// §L7 acceptance shape: 4 sim replicas, 1 killed mid-run — every
/// accepted request gets a terminal response (success or explicit
/// failure, none dropped or hung) and the server drains cleanly.
#[test]
fn four_replicas_one_killed_all_requests_terminal() {
    let mut spec = sim_spec();
    // Small but nonzero costs so the run is long enough for the kill
    // to land mid-stream.
    spec.dstep_ns = 100_000;
    spec.fault.kill_replica = Some(2);
    spec.fault.kill_after_calls = 2;
    let prompts: Vec<Vec<i32>> = (0..48).map(|i| prompt(1 + (i * 11) % 64)).collect();
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), copts(4, 4));
    // drive_concurrent panics on any dropped reply channel, so merely
    // completing proves the none-dropped/none-hung half of the bar.
    let responses = drive_concurrent(&server, &prompts, 8);
    let stats = server.shutdown().expect("supervised crash is not a shutdown error");
    let ok = responses.iter().filter(|r| r.failure.is_none()).count();
    let failed = responses.iter().filter(|r| r.failure.is_some()).count();
    assert_eq!(ok + failed, prompts.len(), "every request terminal");
    assert_eq!(stats.requests, ok);
    assert_eq!(stats.failed, failed);
    assert!(stats.restarts <= 1, "at most the one killed replica is replaced");
    // One kill within budget: everything should in fact succeed.
    assert_eq!(failed, 0, "single crash within retry budget fails nothing");
}

/// With a zero retry budget, a crash turns the in-flight requests into
/// explicit `RetriesExhausted` failures — terminal responses, not
/// dropped channels — while untouched requests still succeed on the
/// replacement replica.
#[test]
fn zero_retry_budget_fails_crashed_requests_explicitly() {
    let mut spec = sim_spec();
    spec.fault.kill_replica = Some(0);
    spec.fault.kill_after_calls = 2;
    let options = ServerOptions { max_retries: 0, ..copts(1, 4) };
    let prompts: Vec<Vec<i32>> = (0..16).map(|i| prompt(2 + (i * 9) % 60)).collect();
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
    let responses = drive_concurrent(&server, &prompts, 4);
    let stats = server.shutdown().expect("recovered server shuts down cleanly");
    let failed: Vec<&Response> = responses.iter().filter(|r| r.failure.is_some()).collect();
    assert!(!failed.is_empty(), "the killed replica's in-flight work must fail explicitly");
    for resp in &failed {
        assert_eq!(resp.failure, Some(FailReason::RetriesExhausted));
        assert!(resp.tokens.is_empty());
    }
    let ok = responses.len() - failed.len();
    assert!(ok > 0, "requests untouched by the crash still succeed");
    assert_eq!(stats.requests, ok);
    assert_eq!(stats.failed, failed.len());
    assert_eq!(stats.retries, 0);
}

/// When the restart budget runs out, the server goes dead instead of
/// hanging: every subsequent request is rejected with an explicit
/// failure, `infer` errors promptly, and `shutdown` reports the crash.
#[test]
fn exhausted_restart_budget_rejects_and_reports() {
    let mut spec = sim_spec();
    spec.fault.panic_rate = 1.0; // every engine call panics
    let options = ServerOptions { max_retries: 0, replica_restarts: 1, ..copts(1, 2) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
    let t0 = Instant::now();
    for i in 0..6 {
        assert!(
            server.infer(prompt(3 + i)).is_err(),
            "request {i} against a dying/dead server must error"
        );
    }
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "rejections must be prompt, not channel hangs"
    );
    assert!(server.shutdown().is_err(), "shutdown reports the exhausted restart budget");
}

/// A dead model thread must surface as an error from `infer`, not a
/// hang: spawning against a nonexistent artifact kills router+replicas
/// at startup.
#[test]
fn infer_errors_when_model_thread_dead() {
    let server = ServerHandle::spawn(
        "definitely-not-an-artifact",
        ServerOptions { batch_window: Duration::from_millis(1), ..Default::default() },
    );
    let err = server.infer(vec![1, 2, 3]);
    assert!(err.is_err(), "infer against a dead server must error, not hang");
    assert!(server.shutdown().is_err(), "shutdown reports the startup failure");
}

/// Satellite regression: a pre-killed router/replica set must reject
/// requests immediately even through a tiny bounded request channel —
/// the old hang window was a blocking `send` whose consumer was gone.
#[test]
fn pre_killed_server_rejects_promptly_through_bounded_channel() {
    let server = ServerHandle::spawn(
        "definitely-not-an-artifact",
        ServerOptions { queue_cap: 1, replica_restarts: 0, ..Default::default() },
    );
    let t0 = Instant::now();
    for _ in 0..4 {
        let resp = server.infer_response(vec![1, 2, 3]);
        match resp {
            Ok(r) => assert_eq!(r.failure, Some(FailReason::NoReplicas)),
            Err(_) => {} // router already gone entirely: also fine
        }
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "a 1-deep channel into a dead server must not block"
    );
    assert!(server.shutdown().is_err());
}

#[test]
fn bucket_ladder_is_monotone_per_request() {
    // Response buckets from a bucketed server always come off the
    // ladder and always fit the prompt.
    let spec = sim_spec();
    let ladder = bucket_lengths(spec.enc_len);
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec.clone()), copts(2, 4));
    for len in [1usize, 7, 8, 9, 30, 33, 64, 100] {
        let r = server.infer(prompt(len)).unwrap();
        assert!(ladder.contains(&r.bucket), "bucket {} for len {len}", r.bucket);
        assert!(r.bucket >= len.min(spec.enc_len));
        assert!(!r.tokens.is_empty() && r.tokens.len() <= spec.dec_len);
        assert_eq!(*r.tokens.last().unwrap(), EOS);
    }
    server.shutdown().unwrap();
}

/// Satellite: reported latency must include time a backpressured
/// request spends blocked in the bounded request channel. With
/// batch_size=1, one replica, a 1-deep request channel, and a ~20 ms
/// decode, six concurrent requests serialize over ~120 ms; most of a
/// late request's life is spent queued. Because the latency clock
/// starts at `Request::new` (before the blocking send), the slowest
/// observed latency must reflect several decode rounds — if the clock
/// started at router admission it would only ever see roughly one
/// round's worth.
#[test]
fn backpressured_infer_latency_includes_queue_time() {
    let mut spec = SimSpec::new(1, 16, 4);
    spec.vocab_size = 211;
    spec.token_ns = 0;
    spec.dtoken_ns = 0;
    spec.dstep_ns = 5_000_000; // 4 steps x 5 ms = 20 ms per monolithic batch
    spec.split_decode = false;
    let options = ServerOptions {
        batch_window: Duration::from_millis(0),
        queue_cap: 1,
        ..opts(1, true)
    };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
    let n = 6;
    let mut joins = Vec::new();
    for i in 0..n {
        let sender = server.sender.clone();
        joins.push(std::thread::spawn(move || {
            let (tx, rx) = std::sync::mpsc::channel();
            sender.send(Request::new(prompt(4 + i), tx)).unwrap();
            rx.recv().unwrap().latency
        }));
    }
    let latencies: Vec<Duration> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.latency_count() as usize, n);
    let max = latencies.iter().max().unwrap();
    assert!(
        *max >= Duration::from_millis(50),
        "queueing time missing from latency: max {max:?} over {latencies:?}"
    );
}

/// Continuous scheduling keeps admitting while slots decode: with slow
/// per-step decode and fast prefill, a server with more slots than
/// batch_size reaches occupancy above one batch's fill.
#[test]
fn continuous_scheduler_overlaps_admission_and_decode() {
    let mut spec = SimSpec::new(2, 32, 16);
    spec.vocab_size = 211;
    spec.token_ns = 0;
    spec.dtoken_ns = 50_000;
    spec.dstep_ns = 200_000;
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), copts(1, 6));
    let prompts: Vec<Vec<i32>> = (0..18).map(|i| prompt(3 + (i * 5) % 28)).collect();
    let responses = drive_concurrent(&server, &prompts, 18);
    assert_eq!(responses.len(), 18);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 18);
    assert!(stats.decode_steps > 0);
    assert!(
        stats.occupancy.mean() > 1.0,
        "slots should host multiple concurrent requests: {:.2}",
        stats.occupancy.mean()
    );
    assert!(stats.occupancy.mean() <= 6.0);
}

/// §L7 deadlines: stuck generations (injected never-EOS rows with a
/// per-step cost) are shed with an explicit `DeadlineExceeded`
/// response once they exceed `request_timeout_ms`, instead of holding
/// a decode slot for the full dec_len.
#[test]
fn deadline_sheds_stuck_generations_mid_decode() {
    let mut spec = sim_spec();
    spec.fault.stuck_every = 1; // every request is a stuck generation
    spec.fault.stuck_step_ns = 20_000_000; // 20 ms per decode step
    let options = ServerOptions { request_timeout_ms: Some(50), ..copts(1, 2) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
    for i in 0..3 {
        let resp = server.infer_response(prompt(4 + i)).expect("terminal response");
        assert_eq!(
            resp.failure,
            Some(FailReason::DeadlineExceeded),
            "a stuck generation past its deadline must be shed"
        );
        assert!(resp.tokens.is_empty());
        assert!(
            resp.latency >= Duration::from_millis(50),
            "shed only after the deadline: {:?}",
            resp.latency
        );
        assert!(
            resp.latency < Duration::from_millis(8 * 20 + 200),
            "shed well before the full stuck decode would finish: {:?}",
            resp.latency
        );
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 0);
    assert_eq!(stats.failed, 3);
    assert_eq!(stats.sheds, 3, "all failures were deadline sheds");
}

/// §L8 acceptance contract: greedy speculative output is
/// token-for-token identical to plain continuous decode — on EOS-first
/// rows (gen_len 1), no-EOS (stuck) rows, and ordinary rows — at the
/// Sim default acceptance model and both extremes (accept-all,
/// reject-all).
#[test]
fn spec_decode_parity_across_acceptance_models() {
    let lens = [1usize, 2, 3, 5, 9, 17, 21, 31, 40, 46, 63, 64, 80];
    let mut base = sim_spec();
    base.fault.stuck_every = 3; // inject some never-EOS rows
    let run = |spec: SimSpec, options: ServerOptions| -> (Vec<Vec<i32>>, ServerStats) {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
        let out = collect(&server, &lens);
        (out, server.shutdown().unwrap())
    };
    let (plain_rows, plain) = run(base.clone(), copts(1, 4));
    assert_eq!(plain.spec.verify_steps, 0, "plain run must not speculate");
    // The workload really covers the edge rows.
    assert!(
        plain_rows.iter().any(|r| r.len() == 1 && r[0] == EOS),
        "needs an EOS-first row: {plain_rows:?}"
    );
    let dec_len = base.dec_len;
    assert!(
        plain_rows.iter().any(|r| r.len() == dec_len && !r.contains(&EOS)),
        "needs a stuck (no-EOS) row: {plain_rows:?}"
    );

    for rate in [0.0, 0.75, 1.0] {
        let mut spec = base.clone();
        spec.draft.as_mut().unwrap().accept_rate = rate;
        let (rows, stats) = run(spec, sopts(1, 4, 4));
        assert_eq!(rows, plain_rows, "spec output != plain decode at rate {rate}");
        assert!(stats.spec.active(), "speculation actually ran at rate {rate}");
        assert_eq!(
            stats.spec.spec_tokens as usize, stats.tokens_generated,
            "every delivered token went through the spec path"
        );
        assert_eq!(stats.spec.draft_steps, 4 * stats.spec.verify_steps);
        assert_eq!(stats.failed, 0);
        if rate == 0.0 {
            assert_eq!(stats.spec.accepted, 0, "reject-all accepts nothing");
            // tokens_per_verify sums over live slots; `collect` drives
            // one request at a time (occupancy 1), so the aggregate
            // equals the per-slot value here: exactly the 1 correction
            // token per verify.
            assert!(
                (stats.spec.tokens_per_verify() - 1.0).abs() < 1e-9,
                "reject-all advances exactly the correction token per verify"
            );
        } else if rate == 1.0 {
            assert!((stats.spec.acceptance_rate() - 1.0).abs() < 1e-12);
            assert!(stats.spec.tokens_per_verify() > 1.0);
        } else {
            let ar = stats.spec.acceptance_rate();
            assert!(ar > 0.0 && ar < 1.0, "mixed-rate acceptance {ar}");
            assert!(
                stats.decode_steps < plain.decode_steps,
                "speculation must need fewer full-model steps: {} vs {}",
                stats.decode_steps,
                plain.decode_steps
            );
        }
    }
}

/// §L8: requesting speculation against an engine that ships no draft
/// model falls back cleanly to plain continuous decode — identical
/// rows, zero spec counters.
#[test]
fn spec_gamma_without_draft_falls_back_to_plain() {
    let lens = [2usize, 9, 17, 40, 64];
    let run = |spec: SimSpec, options: ServerOptions| -> (Vec<Vec<i32>>, ServerStats) {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
        let out = collect(&server, &lens);
        (out, server.shutdown().unwrap())
    };
    let (plain_rows, _) = run(sim_spec(), copts(1, 4));
    let mut no_draft = sim_spec();
    no_draft.draft = None;
    let (rows, stats) = run(no_draft, sopts(1, 4, 4));
    assert_eq!(rows, plain_rows, "fallback must not change outputs");
    assert!(!stats.spec.active(), "no draft: no speculative rounds");
    assert_eq!(stats.spec.drafted, 0);
    assert!(stats.decode_steps > 0, "still ran the continuous path");
    assert_eq!(stats.requests, lens.len());
}

/// §L8 + §L7 compose: speculation with deadlines and stuck rows still
/// sheds expired slots between rounds, and the summary surfaces the
/// spec counters.
#[test]
fn spec_decode_respects_deadlines_and_reports() {
    let mut spec = sim_spec();
    spec.fault.stuck_every = 1; // every request is a stuck generation
    spec.fault.stuck_step_ns = 20_000_000; // 20 ms per verify round
    // Reject-all acceptance: each verify advances exactly one token,
    // so the stuck row deterministically outlives its deadline instead
    // of racing to dec_len within a couple of rounds.
    spec.draft.as_mut().unwrap().accept_rate = 0.0;
    let options = ServerOptions { request_timeout_ms: Some(50), ..sopts(1, 2, 4) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
    let resp = server.infer_response(prompt(4)).expect("terminal response");
    assert_eq!(resp.failure, Some(FailReason::DeadlineExceeded));
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.sheds, 1);
    assert!(stats.spec.active(), "the stuck row did run spec rounds before the shed");
    assert!(stats.summary().contains("spec:"), "summary surfaces spec counters");
}

/// Satellite regression: a request whose deadline is already expired
/// at `Request::new` (zero timeout / client clock skew) is shed at
/// admission with an explicit `DeadlineExceeded` — it never enters a
/// bucket group, batch row, or decode slot.
#[test]
fn pre_expired_requests_shed_at_admission() {
    let options = ServerOptions { request_timeout_ms: Some(0), ..copts(1, 2) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(sim_spec()), options);
    for i in 0..3 {
        let resp = server.infer_response(prompt(4 + i)).expect("terminal response");
        assert_eq!(resp.failure, Some(FailReason::DeadlineExceeded));
        assert_eq!(resp.replica, ROUTER_ID, "shed router-side, not by a replica");
        assert!(resp.tokens.is_empty());
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 0);
    assert_eq!(stats.sheds, 3);
    assert_eq!(stats.failed, 3);
    assert_eq!(stats.batches, 0, "expired requests never formed a batch");
    assert_eq!(stats.prefills, 0, "...or touched a decode slot");
}

/// Same, for an explicit client-stamped deadline already in the past —
/// and a healthy request behind it still decodes normally.
#[test]
fn past_client_deadline_shed_while_healthy_requests_serve() {
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(sim_spec()), copts(1, 2));
    let (tx, rx) = std::sync::mpsc::channel();
    let stale =
        Request::with_deadline(prompt(5), tx, Instant::now() - Duration::from_millis(1));
    server.sender.send(stale).unwrap();
    let resp = rx.recv().expect("terminal response for the expired request");
    assert_eq!(resp.failure, Some(FailReason::DeadlineExceeded));
    assert_eq!(resp.replica, ROUTER_ID);
    assert!(resp.tokens.is_empty());
    let ok = server.infer(prompt(7)).expect("healthy request unaffected");
    assert_eq!(*ok.tokens.last().unwrap(), EOS);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.sheds, 1);
    assert_eq!(stats.requests, 1);
}

/// §L7 drain acceptance: `shutdown()` with in-flight continuous
/// batching slots completes every admitted request before joining —
/// none dropped, none failed when no deadline is set.
#[test]
fn drain_completes_every_in_flight_request() {
    let mut spec = sim_spec();
    spec.dstep_ns = 3_000_000; // ~3 ms per fused step: decode outlives shutdown()
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), copts(1, 4));
    let mut replies = Vec::new();
    for i in 0..8 {
        let (tx, rx) = std::sync::mpsc::channel();
        server.sender.send(Request::new(prompt(3 + i * 7), tx)).unwrap();
        replies.push(rx);
    }
    // Shutdown immediately: most of the 8 requests are still queued or
    // mid-decode. Drain must finish them all.
    let stats = server.shutdown().expect("drain is a clean shutdown");
    for (i, rx) in replies.into_iter().enumerate() {
        let resp = rx.recv().unwrap_or_else(|_| panic!("request {i} dropped during drain"));
        assert!(resp.failure.is_none(), "request {i} failed during drain: {:?}", resp.failure);
        assert!(!resp.tokens.is_empty());
    }
    assert_eq!(stats.requests, 8, "every admitted request completed");
    assert_eq!(stats.failed, 0);
    assert!(
        stats.drained >= 1,
        "some requests should have completed inside the drain window"
    );
}

/// §L7 drain + deadlines: during drain, requests past their deadline
/// are shed with explicit failures and everything else completes —
/// sheds hit only expired requests.
#[test]
fn drain_sheds_only_requests_past_deadline() {
    let mut spec = sim_spec();
    spec.dstep_ns = 5_000_000; // 8 steps x 5 ms = 40 ms per slot wave
    let options = ServerOptions { request_timeout_ms: Some(150), ..copts(1, 2) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
    let mut replies = Vec::new();
    for i in 0..12 {
        let (tx, rx) = std::sync::mpsc::channel();
        server.sender.send(Request::new(prompt(3 + i), tx)).unwrap();
        replies.push(rx);
    }
    let stats = server.shutdown().expect("drain is a clean shutdown");
    let mut ok = 0;
    let mut shed = 0;
    for (i, rx) in replies.into_iter().enumerate() {
        let resp = rx.recv().unwrap_or_else(|_| panic!("request {i} dropped during drain"));
        match resp.failure {
            None => {
                ok += 1;
                assert!(!resp.tokens.is_empty());
            }
            Some(FailReason::DeadlineExceeded) => {
                shed += 1;
                assert!(
                    resp.latency >= Duration::from_millis(150),
                    "shed before its deadline: {:?}",
                    resp.latency
                );
            }
            Some(other) => panic!("drain produced a non-deadline failure: {other:?}"),
        }
    }
    assert_eq!(ok + shed, 12, "every request terminal");
    assert!(ok >= 2, "early waves complete within their deadline");
    assert!(shed >= 1, "late waves are shed, not left hanging");
    assert_eq!(stats.requests, ok);
    assert_eq!(stats.sheds, shed);
    assert_eq!(stats.failed, shed, "only deadline sheds failed");
}

/// §L9 acceptance contract, satellite 1: the paged decode path emits
/// exactly the rows the monolithic continuous path and the §L5
/// batch-level path emit, and the fallback asymmetry holds — only the
/// paged run reports pool metrics.
#[test]
fn paged_vs_monolithic_vs_batch_decode_parity() {
    let lens = [1usize, 3, 8, 9, 15, 17, 31, 33, 40, 63, 64, 80];
    let run = |spec: SimSpec, options: ServerOptions| -> (Vec<Vec<i32>>, ServerStats) {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
        let out = collect(&server, &lens);
        (out, server.shutdown().unwrap())
    };
    // Prefix cache off: pure page-table indirection under test.
    let (paged_rows, paged) = run(paged_spec(16, 32, false), copts(1, 4));
    let (mono_rows, mono) = run(sim_spec(), copts(1, 4));
    let (batch_rows, _) = run(sim_spec(), opts(1, true));
    assert_eq!(paged_rows, mono_rows, "paging must not change emitted tokens");
    assert_eq!(mono_rows, batch_rows, "continuous paths must match the batch loop");

    assert_eq!(paged.requests, lens.len());
    assert_eq!(paged.tokens_generated, mono.tokens_generated);
    assert!(paged.decode_steps > 0, "paged run used the continuous scheduler");
    assert_eq!(paged.failed, 0);

    // Only the paged run carries pool accounting...
    assert_eq!(paged.pool.capacity, 32);
    assert!(paged.pool.samples > 0, "pool occupancy sampled every decode step");
    assert!(paged.pool.peak_used > 0 && paged.pool.peak_used <= 32);
    assert!(paged.summary().contains("pool:"), "summary surfaces pool metrics");
    // ...with no cache or pressure activity at this capacity.
    assert_eq!(paged.pool.prefix_lookups, 0, "cache off: no lookups");
    assert_eq!(paged.pool.evictions, 0);
    assert_eq!(paged.pool.alloc_stalls, 0);
    // The monolithic fallback reports no pool at all.
    assert_eq!(mono.pool.capacity, 0);
    assert_eq!(mono.pool.samples, 0);
    assert!(!mono.summary().contains("pool:"));
}

/// §L9 x §L8: speculative decoding on the paged path (fused
/// `verify_paged` against pool-mapped KV) stays token-for-token
/// identical to plain monolithic continuous decode.
#[test]
fn spec_decode_parity_on_paged_path() {
    let lens = [1usize, 2, 3, 5, 9, 17, 21, 31, 40, 46, 63, 64, 80];
    let run = |spec: SimSpec, options: ServerOptions| -> (Vec<Vec<i32>>, ServerStats) {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
        let out = collect(&server, &lens);
        (out, server.shutdown().unwrap())
    };
    let (plain_rows, plain) = run(sim_spec(), copts(1, 4));
    assert_eq!(plain.spec.verify_steps, 0);
    let (rows, stats) = run(paged_spec(16, 32, true), sopts(1, 4, 4));
    assert_eq!(rows, plain_rows, "paged speculation must not change outputs");
    assert!(stats.spec.active(), "speculation ran on the paged path");
    assert!(stats.spec.verify_steps > 0);
    assert_eq!(
        stats.spec.spec_tokens as usize, stats.tokens_generated,
        "every delivered token went through the paged verify path"
    );
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.pool.capacity, 32);
    assert!(stats.pool.samples > 0);
    // prompt(l) prompts share prefixes by construction, so the cache
    // fired too — proving speculation and prefix reuse compose.
    assert!(stats.pool.prefix_hits > 0, "shared prefixes hit under speculation");
}

/// §L9 admission: a request whose KV footprint exceeds the whole pool
/// is shed with an explicit `PoolExhausted` — a terminal response, not
/// a wedged scheduler — and requests that fit keep serving.
#[test]
fn pool_exhausted_requests_shed_explicitly() {
    // 4 pages x 8 tokens = 32 KV tokens total; dec_len 8 leaves room
    // for prompts bucketed up to 24 tokens. A 40-token prompt needs 9
    // pages — impossible even with every page free.
    let server =
        ServerHandle::spawn_engine(EngineSpec::Sim(paged_spec(8, 4, false)), copts(1, 2));
    let ok = server.infer_response(prompt(6)).expect("terminal response");
    assert!(ok.failure.is_none(), "a fitting request serves normally");
    assert_eq!(*ok.tokens.last().unwrap(), EOS);

    let shed = server.infer_response(prompt(40)).expect("terminal response");
    assert_eq!(shed.failure, Some(FailReason::PoolExhausted));
    assert!(shed.tokens.is_empty());

    let after = server.infer_response(prompt(5)).expect("terminal response");
    assert!(after.failure.is_none(), "the shed must not wedge the scheduler");

    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.sheds, 0, "PoolExhausted is not a deadline shed");
    assert_eq!(stats.pool.alloc_stalls, 0, "impossible != transient shortage");
}

/// §L9 tentpole acceptance: shared prompt prefixes map one physical
/// copy — deterministic hit/saved counters, fewer executed prefill
/// tokens than the cache-off run, and identical output tokens.
/// `prompt(l)` prompts share prefixes by construction (prompt(32) is a
/// prefix of prompt(40)), so serving them sequentially pins the exact
/// chunk-cache arithmetic.
#[test]
fn prefix_cache_reuses_shared_prompt_pages() {
    let lens = [32usize, 40, 48, 64];
    let run = |prefix_cache: bool| -> (Vec<Vec<i32>>, ServerStats) {
        let server = ServerHandle::spawn_engine(
            EngineSpec::Sim(paged_spec(8, 32, prefix_cache)),
            copts(1, 4),
        );
        let out = collect(&server, &lens); // sequential: deterministic cache order
        (out, server.shutdown().unwrap())
    };
    let (rows_on, on) = run(true);
    let (rows_off, off) = run(false);
    assert_eq!(rows_on, rows_off, "prefix reuse must not change emitted tokens");

    // Chunk arithmetic at page_size 8, full chunks over min(len, eff):
    // len 32 -> 4 chunks (all miss, inserted), len 40 -> 5 (4 hit),
    // len 48 -> 6 (5 hit), len 64 -> 8 (6 hit).
    assert_eq!(on.pool.prefix_lookups, 4 + 5 + 6 + 8);
    assert_eq!(on.pool.prefix_hits, 4 + 5 + 6);
    assert_eq!(on.pool.prefill_tokens_saved, (4 + 5 + 6) * 8);
    assert!((on.pool.hit_rate() - 15.0 / 23.0).abs() < 1e-12);
    // The saving is real compute skipped, token for token.
    assert_eq!(
        on.executed_tokens + on.pool.prefill_tokens_saved as usize,
        off.executed_tokens,
        "saved tokens must equal the executed-token reduction"
    );
    // The cache-off baseline did none of this.
    assert_eq!(off.pool.prefix_lookups, 0);
    assert_eq!(off.pool.prefill_tokens_saved, 0);
    // Ample pool: reuse came from sharing, not from eviction churn.
    assert_eq!(on.pool.evictions, 0);
    assert_eq!(on.pool.alloc_stalls, 0);
    for stats in [&on, &off] {
        assert_eq!(stats.requests, lens.len());
        assert_eq!(stats.failed, 0);
    }
}

/// §L9 pool pressure: a pool too small to hold every tenant's cached
/// prefix evicts LRU chunks instead of failing — every request still
/// completes, and the eviction counter reports the churn.
#[test]
fn prefix_cache_evicts_under_pool_pressure() {
    // Distinct 32-token prompts (no shared prefixes): each admission
    // needs 5 pages and caches 4 chunks, so a 10-page pool must evict
    // stale chunks from the third request on.
    let salted = |salt: usize| -> Vec<i32> {
        (0..32).map(|i| ((i * 7 + salt * 13) % 197) as i32 + 2).collect()
    };
    let server =
        ServerHandle::spawn_engine(EngineSpec::Sim(paged_spec(8, 10, true)), copts(1, 2));
    let n = 6;
    for salt in 0..n {
        let resp = server.infer_response(salted(salt)).expect("terminal response");
        assert!(resp.failure.is_none(), "pressure must evict, not fail: {:?}", resp.failure);
        assert_eq!(*resp.tokens.last().unwrap(), EOS);
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, n);
    assert_eq!(stats.failed, 0);
    assert!(stats.pool.evictions > 0, "the pool had to evict cached chunks");
    assert!(stats.pool.peak_used <= 10, "never exceeds physical capacity");
    assert_eq!(stats.pool.prefix_hits, 0, "distinct prompts: churn, not reuse");
    assert!(stats.pool.prefix_lookups > 0);
}

/// §L10 satellite regression: a poison-pill replica (every engine call
/// panics) burns the restart budget through exponential backoff —
/// seconds of wall clock spread over the budget, not a millisecond
/// crash-loop — while the request still reaches an explicit terminal
/// failure and the fleet is never reported dead prematurely.
#[test]
fn poison_pill_replica_burns_restart_budget_slowly() {
    let mut spec = sim_spec();
    spec.fault.panic_rate = 1.0;
    let options =
        ServerOptions { replica_restarts: 3, restart_backoff_ms: 60, ..copts(1, 2) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);
    let t0 = Instant::now();
    let resp = server.infer_response(prompt(5)).expect("terminal response");
    let elapsed = t0.elapsed();
    assert_eq!(resp.failure, Some(FailReason::RetriesExhausted));
    // Crash 1 respawns after >= 0.75 x 60 ms, crash 2 after
    // >= 0.75 x 120 ms; the request fails on its third attempt, so at
    // least those two backoffs are on its clock. Without backoff the
    // whole crash-loop resolves in single-digit milliseconds.
    assert!(
        elapsed >= Duration::from_millis(130),
        "restart budget burned too fast: {elapsed:?}"
    );
    assert!(elapsed < Duration::from_secs(5), "backoff must stay bounded: {elapsed:?}");
    let stats = server.shutdown().expect("budget not exhausted: clean shutdown");
    assert!(
        (2..=3).contains(&stats.restarts),
        "respawns follow the backoff schedule: {}",
        stats.restarts
    );
}

/// §L10 satellite regression (pre-expiry audit on the §L9 paged path):
/// a pending request whose deadline expires while an earlier group's
/// prefill runs is shed *before* the pool gate spends prefix-cache
/// probes or page reservations on it. Neither of B's outcomes here —
/// shed by the between-iterations deadline pass or by the fresh-clock
/// admission check — may cost a prefill or a cache probe.
#[test]
fn paged_admission_sheds_expired_before_spending_pool_work() {
    // token_ns 6 ms: L's bucket-8 prefill holds the replica ~48 ms, so
    // A and B are both pending when the next admission pass starts,
    // and A's own 48 ms prefill pushes the clock past B's deadline
    // before B's candidacy is examined.
    let mut spec = paged_spec(8, 64, true);
    spec.token_ns = 6_000_000;
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), copts(1, 4));

    let (l_tx, l_rx) = std::sync::mpsc::channel();
    server.sender.send(Request::new(prompt(3), l_tx)).unwrap();
    std::thread::sleep(Duration::from_millis(10)); // L ships alone
    let (a_tx, a_rx) = std::sync::mpsc::channel();
    server.sender.send(Request::new(prompt(4), a_tx)).unwrap();
    let (b_tx, b_rx) = std::sync::mpsc::channel();
    let b = Request::with_deadline(
        prompt(64),
        b_tx,
        Instant::now() + Duration::from_millis(60),
    );
    server.sender.send(b).unwrap();

    assert!(l_rx.recv().unwrap().failure.is_none(), "L serves normally");
    assert!(a_rx.recv().unwrap().failure.is_none(), "A serves normally");
    let b_resp = b_rx.recv().unwrap();
    assert_eq!(b_resp.failure, Some(FailReason::DeadlineExceeded));
    assert!(b_resp.tokens.is_empty());

    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.sheds, 1);
    assert_eq!(stats.prefills, 2, "only L and A prefilled; doomed B never did");
    // prompt(3)/prompt(4) are under one full page, so a correct shed
    // leaves the probe counter at exactly zero — B's 8 full chunks are
    // the only possible source of lookups.
    assert_eq!(stats.pool.prefix_lookups, 0, "B's chunks were never probed");
}

/// §L10 tentpole end-to-end: per-tenant token buckets shed a
/// rate-limited tenant's burst with explicit `QueueFull` failures
/// while an unlimited higher-priority tenant is untouched, and the
/// per-tenant meters account every terminal outcome.
#[test]
fn tenant_rate_limit_sheds_and_per_tenant_meters_account() {
    let tenants = parse_tenant_spec("free:0:1:5:4:0;gold:2:4:0:0:2000");
    let options = ServerOptions { tenants, ..copts(1, 4) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(sim_spec()), options);

    // 12 instantaneous free-tenant arrivals against a 4-request burst
    // allowance (refill 5/s = one token per 200 ms: even a slow CI
    // machine refills at most ~1 extra token during the burst).
    let mut free = Vec::new();
    for i in 0..12 {
        let (tx, rx) = std::sync::mpsc::channel();
        server.sender.send(Request::for_tenant(prompt(3 + i), tx, 0, 0)).unwrap();
        free.push(rx);
    }
    let mut gold = Vec::new();
    for i in 0..6 {
        let (tx, rx) = std::sync::mpsc::channel();
        server.sender.send(Request::for_tenant(prompt(20 + i), tx, 1, 2)).unwrap();
        gold.push(rx);
    }

    let free_resp: Vec<Response> = free.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let gold_resp: Vec<Response> = gold.into_iter().map(|rx| rx.recv().unwrap()).collect();

    let free_ok = free_resp.iter().filter(|r| r.failure.is_none()).count();
    let free_shed = free_resp.len() - free_ok;
    assert!((4..=6).contains(&free_ok), "burst allowance honored: {free_ok} served");
    assert!(free_shed >= 6, "the burst beyond the bucket is shed: {free_shed}");
    for r in free_resp.iter().filter(|r| r.failure.is_some()) {
        assert_eq!(r.failure, Some(FailReason::QueueFull), "rate sheds are explicit");
        assert!(r.tokens.is_empty());
    }
    for r in &gold_resp {
        assert!(r.failure.is_none(), "unlimited tenant untouched: {:?}", r.failure);
        assert_eq!(*r.tokens.last().unwrap(), EOS);
    }

    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, free_ok + gold_resp.len());
    assert_eq!(stats.failed, free_shed);
    assert_eq!(stats.sheds, free_shed, "admission rejections count as sheds");
    // Per-tenant meters: outcomes land on the right tenant.
    assert_eq!(stats.tenants.len(), 2);
    assert_eq!(stats.tenants[0].requests as usize, free_ok);
    assert_eq!(stats.tenants[0].sheds as usize, free_shed);
    assert_eq!(stats.tenants[1].requests as usize, gold_resp.len());
    assert_eq!(stats.tenants[1].sheds, 0);
    // Gold's 2 s SLO is trivially met by the zero-cost sim: perfect
    // per-tenant goodput; free (no SLO) counts completions as goodput.
    assert_eq!(stats.tenants[1].slo_hits as usize, gold_resp.len());
    assert!((stats.tenants[1].goodput_ratio() - 1.0).abs() < 1e-12);
    assert_eq!(stats.tenants[0].slo_hits as usize, free_ok);
}

// ---------------------------------------------------------------- §L11

/// A healthy successor version: identical tokens (salt 0), slightly
/// different cost. `SimSwapSpec::apply` is the deploy analogue of
/// `ChaosSpec::apply`.
fn new_version(base: &SimSpec) -> SimSpec {
    SimSwapSpec { cost_mult: 0.9, bad: BadVersionMode::None }.apply(base)
}

/// §L11 tentpole: a rolling swap on a live fleet promotes every
/// replica through the canary gates, completes, and accounts every
/// request to exactly one version row.
#[test]
fn rolling_swap_completes_with_zero_lost_requests() {
    let base = sim_spec();
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(base.clone()), copts(2, 4));

    // Concurrent client load riding across the whole rollout.
    let n_reqs = 96usize;
    let mut clients = Vec::new();
    for c in 0..4usize {
        let sender = server.sender.clone();
        clients.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for i in (c..n_reqs).step_by(4) {
                let (tx, rx) = std::sync::mpsc::channel();
                sender.send(Request::new(prompt(3 + (i % 40)), tx)).expect("router accepts");
                out.push(rx.recv().expect("terminal response"));
                std::thread::sleep(Duration::from_millis(1));
            }
            out
        }));
    }
    let status = server.deploy(EngineSpec::Sim(new_version(&base)));
    assert_eq!(
        status,
        DeployStatus::Completed { version: 1, swapped: 2 },
        "both replicas promoted"
    );
    assert_eq!(server.deploy_status(), status, "status snapshot agrees with the waiter");

    let responses: Vec<Response> =
        clients.into_iter().flat_map(|c| c.join().expect("client thread")).collect();
    assert_eq!(responses.len(), n_reqs, "exactly one terminal response per request");
    for r in &responses {
        assert!(r.failure.is_none(), "no request lost to the swap: {:?}", r.failure);
        assert_eq!(*r.tokens.last().unwrap(), EOS);
    }

    // Post-swap traffic lands on v1 and emits identical tokens (the
    // healthy successor differs only in cost).
    let after = collect(&server, &[5, 9, 17]);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.deploy.canary_pass, 2, "one canary verdict per replica");
    assert_eq!(stats.deploy.canary_fail, 0);
    assert_eq!(stats.deploy.rollbacks, 0);
    assert_eq!(stats.deploy.completed, 1);
    // Partition-of-global invariant: every completion and failure is
    // in exactly one version row.
    let vreq: u64 = stats.deploy.versions.iter().map(|m| m.requests).sum();
    let vfail: u64 = stats.deploy.versions.iter().map(|m| m.failed).sum();
    assert_eq!(vreq as usize, stats.requests, "version rows partition completions");
    assert_eq!(vfail as usize, stats.failed, "version rows partition failures");
    assert!(stats.deploy.version_requests(1) >= after.len() as u64, "post-swap work is on v1");
    assert!(stats.summary().contains("deploy:"), "rollout surfaces in the summary");
}

/// §L11: a wrong-token successor is caught at the token-parity probe
/// gate — it serves zero requests, the rollout rolls back, and the
/// fleet keeps emitting old-version tokens.
#[test]
fn bad_version_rolls_back_with_token_parity() {
    let base = sim_spec();
    let lens = [3usize, 9, 17, 33];

    // Old-version ground truth from a clean server.
    let clean = ServerHandle::spawn_engine(EngineSpec::Sim(base.clone()), copts(1, 4));
    let want = collect(&clean, &lens);
    clean.shutdown().unwrap();

    let server = ServerHandle::spawn_engine(EngineSpec::Sim(base.clone()), copts(1, 4));
    let bad = SimSwapSpec { cost_mult: 0.0, bad: BadVersionMode::WrongTokens }.apply(&base);
    let status = server.deploy(EngineSpec::Sim(bad));
    match &status {
        DeployStatus::RolledBack { version: 2.., .. } => {
            panic!("version numbering drifted: {status}")
        }
        DeployStatus::RolledBack { swapped: 0, reason, .. } => {
            assert!(reason.contains("token-parity"), "gate named in the reason: {reason}")
        }
        other => panic!("expected a parity rollback, got {other}"),
    }

    // The fleet still answers with old-version tokens.
    assert_eq!(collect(&server, &lens), want, "token parity with the old version pinned");
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.deploy.rollbacks, 1);
    assert_eq!(stats.deploy.canary_fail, 1);
    assert_eq!(stats.deploy.canary_pass, 0);
    assert_eq!(
        stats.deploy.version_requests(1),
        0,
        "the bad version answered zero client requests"
    );
}

/// §L11: a successor broken badly enough to panic on first execute
/// crashes at its probe decode and rolls back — without spending §L7
/// restart budget or leaving the fleet smaller.
#[test]
fn panicking_version_crash_rolls_back() {
    let base = sim_spec();
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(base.clone()), copts(1, 2));
    let bad = SimSwapSpec { cost_mult: 0.0, bad: BadVersionMode::Panic }.apply(&base);
    let status = server.deploy(EngineSpec::Sim(bad));
    match &status {
        DeployStatus::RolledBack { swapped: 0, reason, .. } => {
            assert!(reason.contains("crashed"), "crash named in the reason: {reason}")
        }
        other => panic!("expected a crash rollback, got {other}"),
    }
    // The replacement serves old-version traffic normally.
    let rows = collect(&server, &[5, 12]);
    for row in &rows {
        assert_eq!(*row.last().unwrap(), EOS);
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.deploy.rollbacks, 1);
    assert_eq!(stats.restarts, 0, "rollout lifecycle exits spend no §L7 restart budget");
}

/// §L11 satellite: a new version that fails validation (artifact that
/// cannot load, or a geometry mismatch) is a typed `Failed` — the
/// serving fleet is never touched.
#[test]
fn invalid_new_version_fails_before_any_drain() {
    let base = sim_spec();
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(base.clone()), copts(1, 2));

    // Artifact that cannot load (no such directory).
    let status = server.deploy_artifact("no-such-artifact-l11");
    match &status {
        DeployStatus::Failed { reason, .. } => {
            assert!(reason.contains("validation"), "load error surfaced: {reason}")
        }
        other => panic!("expected Failed, got {other}"),
    }

    // Geometry mismatch (different enc_len) is equally typed.
    let mut wrong = base.clone();
    wrong.enc_len = base.enc_len * 2;
    let status = server.deploy(EngineSpec::Sim(wrong));
    match &status {
        DeployStatus::Failed { reason, .. } => {
            assert!(reason.contains("geometry"), "mismatch surfaced: {reason}")
        }
        other => panic!("expected Failed, got {other}"),
    }

    // The fleet served through both rejected rollouts untouched.
    let rows = collect(&server, &[4, 8]);
    assert_eq!(rows.len(), 2);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.deploy.rollbacks, 0, "nothing was drained for a rejected version");
    assert_eq!(stats.requests, 2);
}

/// §L11 satellite: `shutdown()` during an in-flight rollout aborts it
/// cleanly — the full §L7 drain still happens, every request gets a
/// terminal response, and the aborted rollout lands in the shutdown
/// stats.
#[test]
fn shutdown_during_rollout_aborts_cleanly() {
    let base = sim_spec();
    // A probation window far longer than the test keeps the rollout
    // in flight until shutdown interrupts it.
    let options = ServerOptions {
        deploy: DeployOptions { probation: 10_000, probation_ms: 60_000, ..deploy_opts() },
        ..copts(2, 2)
    };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(base.clone()), options);
    let before = collect(&server, &[5, 9]);
    assert_eq!(before.len(), 2);

    let _seq = server.deploy_start(EngineSpec::Sim(new_version(&base)));
    // Wait until the rollout is genuinely mid-flight (a canary is up
    // or a drain is pending) before pulling the plug.
    let t0 = Instant::now();
    while !matches!(server.deploy_status(), DeployStatus::InProgress { .. }) {
        assert!(t0.elapsed() < Duration::from_secs(10), "rollout never started");
        std::thread::sleep(Duration::from_millis(2));
    }
    std::thread::sleep(Duration::from_millis(30));

    let stats = server.shutdown().expect("graceful drain despite the rollout");
    assert_eq!(stats.deploy.aborted, 1, "aborted rollout reported in shutdown stats");
    assert!(stats.summary().contains("1 aborted"), "surfaced in the summary");
    assert_eq!(stats.requests, 2, "pre-rollout traffic fully accounted");
}

// ---------------------------------------------------------------------------
// §L12: tensor-parallel execution groups.
// ---------------------------------------------------------------------------

/// §L12 pinned link model (env-free so an exported `ALTUP_TP_*` knob
/// cannot skew these tests): the bench's altup-25g operating point.
fn pinned_collective() -> CollectiveSpec {
    CollectiveSpec {
        d_model: 1024,
        active_width: 256,
        elem_bytes: 2,
        link_bps: 25.0e9,
        latency_ns: 500,
        syncs_per_step: 12,
        partitioned_frac: 0.85,
    }
}

/// `sim_spec` with the pinned collective model attached.
fn tp_spec() -> SimSpec {
    SimSpec { collective: pinned_collective(), ..sim_spec() }
}

/// One 2-way TP group serving the whole fleet.
fn topts(slots: usize) -> ServerOptions {
    ServerOptions { tp: 2, ..copts(1, slots) }
}

/// §L12 acceptance pin: sharding a continuous-batching unit into a
/// 2-way group must not change a single sampled token vs the same
/// model served whole, while the collective/device ledgers diverge
/// exactly as the topology says they should.
#[test]
fn tp_group_matches_single_replica_tokens_and_accounts_collectives() {
    let lens = [1usize, 5, 8, 17, 33, 64, 80];

    let single = ServerHandle::spawn_engine(EngineSpec::Sim(tp_spec()), copts(1, 4));
    let want = collect(&single, &lens);
    let sstats = single.shutdown().unwrap();
    assert_eq!(sstats.devices, 1, "a whole-model unit is one device");
    assert_eq!(sstats.collectives, 0, "an unsharded model never syncs");
    assert_eq!(sstats.collective_ns, 0);

    let group = ServerHandle::spawn_engine(EngineSpec::Sim(tp_spec()), topts(4));
    let got = collect(&group, &lens);
    let gstats = group.shutdown().unwrap();
    assert_eq!(got, want, "sharding must not change sampled tokens");
    assert_eq!(gstats.devices, 2, "one 2-way group occupies two devices");
    assert!(gstats.collectives > 0, "every sharded step pays its all-reduce rounds");
    assert!(gstats.collective_ns > 0, "pinned nonzero link latency accrues sim time");
    assert_eq!(gstats.requests, lens.len());
    assert_eq!(gstats.failed, 0);
}

/// §L12 x §L8/§L9: TP parity holds on the paged-pool and speculative
/// decode paths too — the sharded leader carries the same slot
/// geometry, draft schedule, and page ledger as a whole-model unit.
#[test]
fn tp_parity_holds_on_paged_and_speculative_paths() {
    let lens = [2usize, 9, 16, 31, 40, 64];

    let plain = ServerHandle::spawn_engine(EngineSpec::Sim(tp_spec()), copts(1, 4));
    let want = collect(&plain, &lens);
    plain.shutdown().unwrap();

    // Paged decode state behind a 2-way group.
    let paged = SimSpec { collective: pinned_collective(), ..paged_spec(16, 64, false) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(paged), topts(4));
    let got = collect(&server, &lens);
    let stats = server.shutdown().unwrap();
    assert_eq!(got, want, "paged TP decode is token-identical to whole-model");
    assert_eq!(stats.devices, 2);
    assert!(stats.collectives > 0);

    // Speculative decode (γ=4) behind a 2-way group.
    let server = ServerHandle::spawn_engine(
        EngineSpec::Sim(tp_spec()),
        ServerOptions { tp: 2, ..sopts(1, 4, 4) },
    );
    let got = collect(&server, &lens);
    let stats = server.shutdown().unwrap();
    assert_eq!(got, want, "speculative TP decode is token-identical to whole-model");
    assert_eq!(stats.devices, 2);
    assert!(stats.collectives > 0, "draft/verify rounds still pay the verify collectives");
}

/// §L12 x §L7: killing a FOLLOWER shard takes the whole group down
/// atomically — the supervisor respawns a full 2-way group, requeues
/// the in-flight work, and every request completes with the healthy
/// run's exact tokens.
#[test]
fn tp_follower_shard_kill_respawns_the_whole_group() {
    let prompts: Vec<Vec<i32>> = (0..24).map(|i| prompt(1 + (i * 7) % 64)).collect();

    let healthy = {
        let server = ServerHandle::spawn_engine(EngineSpec::Sim(tp_spec()), topts(4));
        let out = drive_concurrent(&server, &prompts, 4);
        server.shutdown().unwrap();
        out
    };

    let mut spec = tp_spec();
    // The kill schedule routes to shard 1 (`FaultSpec::for_shard`), so
    // the panic fires on a follower, not the cost-carrying leader —
    // the group must still die and respawn as one unit.
    spec.fault.kill_replica = Some(0);
    spec.fault.kill_after_calls = 2;
    spec.fault.kill_shard = 1;
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), topts(4));
    let responses = drive_concurrent(&server, &prompts, 4);
    let stats = server.shutdown().expect("group crash recovers cleanly");

    for (resp, h) in responses.iter().zip(healthy.iter()) {
        assert!(
            resp.failure.is_none(),
            "one group crash within the retry budget must not fail requests: {:?}",
            resp.failure
        );
        assert_eq!(&resp.tokens, &h.tokens, "post-respawn decode is deterministic");
    }
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.restarts, 1, "exactly one replacement group spawned");
    assert!(stats.retries >= 1, "the dead group's in-flight work was requeued");
    assert_eq!(stats.devices, 4, "crashed + replacement incarnations: two devices each");
}

// ---------------------------------------------------------------- §L13

/// §L13 sim spec with nonzero per-token/per-step costs so every phase
/// span has measurable duration (the zero-cost `sim_spec` would make
/// the phase-sum invariant trivially true at 0 ns).
fn traced_spec() -> SimSpec {
    let mut spec = sim_spec();
    spec.token_ns = 2_000;
    spec.dtoken_ns = 20_000;
    spec.dstep_ns = 100_000;
    spec
}

/// §L13 tentpole invariant: for every traced request, the five
/// top-level phase spans (admission-queue, qos-queue, router-dispatch,
/// prefill, decode) tile the request's [arrival, retirement] interval —
/// the sum of their durations reproduces the end-to-end latency within
/// 5%, and consecutive phases never overlap or leave gaps beyond that
/// bound. This is what makes the attribution trustworthy: phase shares
/// are shares *of the latency the client saw*.
#[test]
fn traced_request_phase_spans_sum_to_e2e_latency() {
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(traced_spec()), tropts(2, 4, 1.0));
    let prompts: Vec<Vec<i32>> = (0..32).map(|i| prompt(1 + (i * 5) % 64)).collect();
    let responses = drive_concurrent(&server, &prompts, 4);
    for r in &responses {
        assert!(r.failure.is_none(), "healthy run: {:?}", r.failure);
    }
    let stats = server.shutdown().unwrap();

    let attrs = trace::per_request(stats.trace.spans());
    assert_eq!(attrs.len(), prompts.len(), "sample 1.0 traces every request");
    assert_eq!(stats.trace.dropped_spans, 0, "default ring holds this workload");
    for a in &attrs {
        let e2e = a.e2e_ns();
        let sum = a.top_level_ns();
        assert!(e2e > 0, "req {} recorded no time", a.req);
        for p in Phase::TOP_LEVEL {
            assert!(
                a.phase_ns[p.index()] > 0 || matches!(p, Phase::QosQueue),
                "req {} missing top-level phase {}",
                a.req,
                p.as_str()
            );
        }
        let gap = (sum as f64 - e2e as f64).abs() / e2e as f64;
        assert!(
            gap <= 0.05,
            "req {}: phase sum {sum} ns vs e2e {e2e} ns diverges {:.1}%",
            a.req,
            gap * 100.0
        );
    }
    // Span ordering within a request: phases close in pipeline order.
    let order = [
        Phase::AdmissionQueue,
        Phase::QosQueue,
        Phase::RouterDispatch,
        Phase::Prefill,
        Phase::Decode,
    ];
    for a in &attrs {
        let mut ends: Vec<(usize, u64)> = Vec::new();
        for s in stats.trace.spans().filter(|s| s.req == a.req) {
            if let Some(pos) = order.iter().position(|p| *p == s.phase) {
                ends.push((pos, s.end_ns));
            }
        }
        ends.sort();
        for w in ends.windows(2) {
            assert!(
                w[0].1 <= w[1].1,
                "req {}: phase {} ends after {}",
                a.req,
                order[w[0].0].as_str(),
                order[w[1].0].as_str()
            );
        }
    }
    // The nested meters saw the same serving work the spans did.
    assert!(stats.trace.phases.get(Phase::DecodeIter).0 > 0, "decode iterations metered");
    assert!(stats.trace.phases.get(Phase::Prefill).0 > 0, "prefill groups metered");
    assert!(stats.summary().contains("trace:"), "trace section surfaces in the summary");
    // And the timeline binned completions for the same requests.
    let done: u64 = stats.trace.timeline.windows.values().map(|w| w.done).sum();
    assert_eq!(done as usize, prompts.len(), "timeline completions match served requests");
}

/// §L13: deterministic sampling — the sampled set is a pure function of
/// prompt content and seed, so two identical runs trace the same
/// requests (pinned via the prefill spans' prompt-length payloads), and
/// a mid fraction traces a strict subset.
#[test]
fn trace_sampling_is_deterministic_across_runs() {
    let run = || {
        let server =
            ServerHandle::spawn_engine(EngineSpec::Sim(traced_spec()), tropts(1, 4, 0.5));
        // Distinct prompt lengths => distinct content hashes.
        let responses = drive_concurrent(
            &server,
            &(0..24).map(|i| prompt(1 + i * 2)).collect::<Vec<_>>(),
            2,
        );
        assert!(responses.iter().all(|r| r.failure.is_none()));
        let stats = server.shutdown().unwrap();
        let mut traced: Vec<i64> = stats
            .trace
            .spans()
            .filter(|s| s.phase == Phase::Prefill && s.req != 0)
            .map(|s| s.value)
            .collect();
        traced.sort_unstable();
        traced
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same workload + seed must sample the same request set");
    assert!(!a.is_empty(), "sample 0.5 over 24 distinct prompts traces some");
    assert!(a.len() < 24, "...but not all");
}

/// §L13: a ring past capacity drops the *oldest* spans and says so —
/// `dropped_spans` surfaces through the stats merge instead of lying
/// by omission.
#[test]
fn trace_ring_overflow_drops_oldest_and_surfaces_count() {
    let options = ServerOptions { trace_ring: 8, ..tropts(1, 4, 1.0) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(traced_spec()), options);
    let responses =
        drive_concurrent(&server, &(0..24).map(|i| prompt(1 + i * 2)).collect::<Vec<_>>(), 2);
    assert!(responses.iter().all(|r| r.failure.is_none()));
    let stats = server.shutdown().unwrap();
    assert!(
        stats.trace.dropped_spans > 0,
        "24 requests x >=4 spans each cannot fit 8-deep rings silently"
    );
    // What remains is the newest tail: every retained worker span ends
    // no earlier than the oldest drop horizon — cheap proxy: retained
    // count respects the per-collector cap (router ring + one ring per
    // replica incarnation).
    assert!(stats.trace.span_count() <= 8 * 2, "retention bounded by the ring caps");
    let max_end = stats.trace.spans().map(|s| s.end_ns).max().unwrap();
    assert!(
        stats.trace.spans().any(|s| s.end_ns == max_end),
        "the newest span survives an overflow"
    );
}

/// §L13 satellite: the §L10 overload ladder leaves timestamped trace
/// events — a burst well past capacity escalates at least one rung,
/// and sustained calm walks it back to level 0 before shutdown.
#[test]
fn overload_ladder_escalations_leave_trace_events_and_calm_returns_to_zero() {
    let mut spec = sim_spec();
    // Slow enough that a 60-request burst sustains queue depth far past
    // 2x the slot hint for the 300 ms escalation hold.
    spec.dstep_ns = 4_000_000;
    let tenants = parse_tenant_spec("free:0:1:0:0:0;gold:2:4:0:0:0");
    let options = ServerOptions { tenants, ..tropts(1, 2, 1.0) };
    let server = ServerHandle::spawn_engine(EngineSpec::Sim(spec), options);

    let mut rxs = Vec::new();
    for i in 0..60 {
        let (tx, rx) = std::sync::mpsc::channel();
        server.sender.send(Request::for_tenant(prompt(1 + (i % 40)), tx, i % 2, 0)).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let _ = rx.recv().expect("terminal response");
    }
    // Calm: hold the server idle past the 500 ms de-escalation window
    // (ladder moves one rung per window — allow a few).
    std::thread::sleep(Duration::from_millis(1800));
    let stats = server.shutdown().unwrap();

    let ladder: Vec<(u64, i64)> = stats
        .trace
        .spans()
        .filter(|s| s.phase == Phase::LadderLevel)
        .map(|s| (s.start_ns, s.value))
        .collect();
    assert!(!ladder.is_empty(), "the burst must move the ladder");
    let peak = ladder.iter().map(|(_, l)| *l).max().unwrap();
    assert!(peak >= 1, "burst escalates at least one rung (peak {peak})");
    let last = ladder.iter().max_by_key(|(at, _)| *at).unwrap();
    assert_eq!(last.1, 0, "calm de-escalates back to level 0 (events: {ladder:?})");
    // Every transition is timestamped and the sequence moves one rung
    // at a time in event order.
    let mut seq = ladder.clone();
    seq.sort();
    let mut prev = 0i64;
    for (_, l) in &seq {
        assert_eq!((l - prev).abs(), 1, "ladder moves one rung per event: {seq:?}");
        prev = *l;
    }
}
