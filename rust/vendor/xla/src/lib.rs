//! Offline stand-in for the xla-rs PJRT bindings.
//!
//! The build image cannot link the real XLA runtime, so this crate
//! implements the xla-rs API surface the coordinator uses with
//! host-backed storage:
//!
//! - `Literal` is a real host tensor container (create / read back /
//!   tuple decompose all work).
//! - `PjRtBuffer` is a "device" buffer backed by host memory: upload
//!   (`PjRtClient::buffer_from_host_literal`), download
//!   (`to_literal_sync`), and tuple decomposition (`untuple`) are
//!   fully functional, so the runtime's device-resident state cache
//!   and checkpoint-coherence machinery can be exercised in tests.
//! - `PjRtClient::compile` / `PjRtLoadedExecutable::execute*` return
//!   `Error::BackendUnavailable`: executing HLO requires the real
//!   xla-rs bindings (repoint the `xla` path dependency in
//!   rust/Cargo.toml; the API here is call-compatible, with `untuple`
//!   mapping onto PJRT's untuple_result).

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

#[derive(Debug)]
pub enum Error {
    /// Operation needs a real PJRT backend (HLO compile/execute).
    BackendUnavailable(String),
    /// Shape/type misuse of a literal or buffer.
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BackendUnavailable(m) => {
                write!(f, "xla stub: {m} (link the real xla-rs bindings to execute HLO)")
            }
            Error::Msg(m) => write!(f, "xla stub: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(m: impl fmt::Display) -> Result<T> {
    Err(Error::Msg(m.to_string()))
}

// ---------------------------------------------------------------------
// Element / primitive types
// ---------------------------------------------------------------------

/// Array element type (construction-side name, mirroring xla-rs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

/// Shape primitive type (readback-side name, mirroring xla-rs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    U32,
    Tuple,
}

impl ElementType {
    pub fn primitive_type(self) -> PrimitiveType {
        match self {
            ElementType::F32 => PrimitiveType::F32,
            ElementType::S32 => PrimitiveType::S32,
            ElementType::U32 => PrimitiveType::U32,
        }
    }
    pub fn element_size_in_bytes(self) -> usize {
        4
    }
}

/// Rust scalar types storable in a `Literal`.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn to_bytes(self) -> [u8; 4];
    fn from_bytes(b: [u8; 4]) -> Self;
}

macro_rules! native {
    ($t:ty, $et:expr) => {
        impl NativeType for $t {
            const ELEMENT_TYPE: ElementType = $et;
            fn to_bytes(self) -> [u8; 4] {
                self.to_le_bytes()
            }
            fn from_bytes(b: [u8; 4]) -> Self {
                <$t>::from_le_bytes(b)
            }
        }
    };
}
native!(f32, ElementType::F32);
native!(i32, ElementType::S32);
native!(u32, ElementType::U32);

// ---------------------------------------------------------------------
// Shapes
// ---------------------------------------------------------------------

/// Dense array shape: primitive type + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    prim: PrimitiveType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
    pub fn primitive_type(&self) -> PrimitiveType {
        self.prim
    }
    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }
}

// ---------------------------------------------------------------------
// Literals (host tensors)
// ---------------------------------------------------------------------

/// A host-side XLA literal: a dense array or a tuple of literals.
#[derive(Debug, Clone)]
pub enum Literal {
    Array { prim: PrimitiveType, dims: Vec<i64>, bytes: Vec<u8> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// A rank-0 literal holding one scalar.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal::Array {
            prim: T::ELEMENT_TYPE.primitive_type(),
            dims: Vec::new(),
            bytes: v.to_bytes().to_vec(),
        }
    }

    /// Build a dense literal from raw bytes in row-major order.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        let want = n * ty.element_size_in_bytes();
        if untyped_data.len() != want {
            return err(format!(
                "data size {} != {} for shape {dims:?}",
                untyped_data.len(),
                want
            ));
        }
        Ok(Literal::Array {
            prim: ty.primitive_type(),
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: untyped_data.to_vec(),
        })
    }

    /// Assemble a tuple literal (the stub's analogue of xla-rs
    /// `Literal::tuple`; used by tests and the fake execute path).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal::Tuple(elements)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { prim, dims, .. } => {
                Ok(ArrayShape { prim: *prim, dims: dims.clone() })
            }
            Literal::Tuple(_) => err("array_shape on a tuple literal"),
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { bytes, .. } => bytes.len() / 4,
            Literal::Tuple(es) => es.iter().map(|e| e.element_count()).sum(),
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            Literal::Array { bytes, .. } => bytes.len(),
            Literal::Tuple(es) => es.iter().map(|e| e.size_bytes()).sum(),
        }
    }

    /// Read the array back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { prim, bytes, .. } => {
                if *prim != T::ELEMENT_TYPE.primitive_type() {
                    return err(format!(
                        "to_vec type mismatch: literal is {prim:?}, asked for {:?}",
                        T::ELEMENT_TYPE
                    ));
                }
                Ok(bytes
                    .chunks_exact(4)
                    .map(|c| T::from_bytes([c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            Literal::Tuple(_) => err("to_vec on a tuple literal"),
        }
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(es) => Ok(es),
            Literal::Array { .. } => err("to_tuple on a non-tuple literal"),
        }
    }
}

// ---------------------------------------------------------------------
// HLO text artifacts
// ---------------------------------------------------------------------

/// Parsed-enough HLO module: the stub validates and holds the text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(Path::new(path))
            .map_err(|e| Error::Msg(format!("reading HLO text {path}: {e}")))?;
        if !text.contains("HloModule") {
            return err(format!("{path} does not look like HLO text"));
        }
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation (opaque handle around the module).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }
    pub fn module_text(&self) -> &str {
        &self.proto.text
    }
}

// ---------------------------------------------------------------------
// PJRT client / executable / buffers
// ---------------------------------------------------------------------

/// PJRT client. The stub's "device" is host memory.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::BackendUnavailable("compile".to_string()))
    }

    /// Copy a host literal into a device buffer.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: literal.clone() })
    }
}

/// A compiled executable. Unreachable in the stub (compile fails), but
/// the API is kept call-compatible with xla-rs.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host literal arguments (uploads internally).
    /// Returns per-device output buffers.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("execute".to_string()))
    }

    /// Execute with device-resident buffer arguments (no uploads).
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("execute_b".to_string()))
    }
}

/// A device buffer (host-backed in the stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Synchronous device -> host copy.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }

    /// Decompose a tuple-rooted buffer into per-element device buffers
    /// without a host round-trip (PJRT untuple_result semantics). A
    /// non-tuple buffer comes back unchanged as a single element.
    pub fn untuple(&self) -> Result<Vec<PjRtBuffer>> {
        match &self.literal {
            Literal::Tuple(es) => {
                Ok(es.iter().map(|e| PjRtBuffer { literal: e.clone() }).collect())
            }
            Literal::Array { .. } => Ok(vec![self.clone()]),
        }
    }

    pub fn on_device_size_bytes(&self) -> usize {
        self.literal.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.primitive_type(), PrimitiveType::F32);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_literals() {
        assert_eq!(Literal::scalar(7i32).to_vec::<i32>().unwrap(), vec![7]);
        assert_eq!(Literal::scalar(0.5f32).to_vec::<f32>().unwrap(), vec![0.5]);
        assert_eq!(Literal::scalar(9u32).array_shape().unwrap().dims().len(), 0);
    }

    #[test]
    fn bad_sizes_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 7]).is_err()
        );
    }

    #[test]
    fn buffer_upload_download_untuple() {
        let client = PjRtClient::cpu().unwrap();
        let a = Literal::scalar(1.0f32);
        let b = Literal::scalar(2i32);
        let tup = Literal::tuple(vec![a, b]);
        let buf = client.buffer_from_host_literal(None, &tup).unwrap();
        let parts = buf.untuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0]);
        assert_eq!(parts[1].to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![2]);
        // non-tuple untuple is identity
        let solo = client.buffer_from_host_literal(None, &Literal::scalar(3u32)).unwrap();
        assert_eq!(solo.untuple().unwrap().len(), 1);
    }

    #[test]
    fn execute_requires_backend() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        assert!(matches!(client.compile(&comp), Err(Error::BackendUnavailable(_))));
    }
}
