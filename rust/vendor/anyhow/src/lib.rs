//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so the workspace vendors
//! the subset of the anyhow API it actually uses: the boxed `Error`
//! with context frames, the `Result` alias, the `Context` extension
//! trait on `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!`
//! macros. Semantics mirror upstream anyhow: `Display` prints the
//! outermost message, `Debug` prints the whole cause chain.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` (the error type defaults like upstream).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error. Like upstream anyhow, this deliberately
/// does NOT implement `std::error::Error`, which is what allows the
/// blanket `From<E: std::error::Error>` conversion below.
pub struct Error {
    /// Context frames, outermost (most recently attached) first. When
    /// `root` is `None` the last frame is the original message.
    frames: Vec<String>,
    /// The original typed error, if this `Error` was converted from one.
    root: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()], root: None }
    }

    /// Attach a higher-level context message (becomes the `Display`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The full cause chain, outermost message first.
    pub fn chain(&self) -> Vec<String> {
        let mut out = self.frames.clone();
        if let Some(root) = &self.root {
            out.push(root.to_string());
            let mut src = root.source();
            while let Some(s) = src {
                out.push(s.to_string());
                src = s.source();
            }
        }
        out
    }

    /// Borrow the original typed error, if any.
    pub fn root_cause_dyn(&self) -> Option<&(dyn StdError + Send + Sync + 'static)> {
        self.root.as_deref()
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { frames: Vec::new(), root: Some(Box::new(e)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.frames.first() {
            Some(top) => f.write_str(top),
            None => match &self.root {
                Some(root) => write!(f, "{root}"),
                None => f.write_str("unknown error"),
            },
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain();
        let mut it = chain.iter();
        if let Some(top) = it.next() {
            write!(f, "{top}")?;
        }
        let rest: Vec<&String> = it.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, msg) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn from_and_display() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "no such file");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening checkpoint").unwrap_err();
        assert_eq!(e.to_string(), "opening checkpoint");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("no such file"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing field {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "missing field k");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
