//! Quickstart: pretrain a micro AltUp model for 50 steps, evaluate, and
//! greedy-decode one batch — the smallest end-to-end exercise of all
//! three layers (Pallas-validated kernels -> AOT HLO -> rust runtime).
//!
//!     make artifacts && cargo run --release --example quickstart

use altup::coordinator::metrics::MetricsLog;
use altup::coordinator::trainer::{DataSource, TrainOptions, Trainer};
use altup::data::batcher::PretrainBatcher;
use altup::data::tokenizer::Tokenizer;
use altup::runtime::artifact::load_named;
use altup::runtime::client::Client;
use altup::runtime::session::Session;

fn main() -> anyhow::Result<()> {
    let client = Client::cpu()?;
    println!("PJRT platform: {}", client.platform());

    // 1. Load the AOT artifact (built by `make artifacts`).
    let artifact = load_named("micro-altup")?;
    let cfg = artifact.config.clone();
    println!(
        "model: {} — variant={} K={} d={} ({} params)",
        artifact.name,
        cfg.variant.as_str(),
        cfg.k,
        cfg.d_model,
        artifact.param_count_total
    );

    // 2. Pretrain on the synthetic corpus for 50 steps.
    let session = Session::open(&client, artifact, 0)?;
    let batcher =
        PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 42);
    let mut trainer =
        Trainer::new(session, DataSource::Pretrain(batcher), MetricsLog::in_memory());
    let opts = TrainOptions { steps: 50, warmup: 1000, log_every: 10, ..Default::default() };
    let (ema, sps) = trainer.run(&client, &opts)?;
    println!("trained 50 steps: loss_ema={ema:.3} at {sps:.2} steps/s");

    // 3. Held-out evaluation.
    let ev = trainer.eval(&client, 4)?;
    println!("validation: {}", ev.summary());

    // 4. Greedy decode a batch of corrupted inputs.
    let mut val = PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 7);
    let batch = val.next_batch();
    let rows = trainer.session.decode(&client, &batch.enc_tokens)?;
    let tk = Tokenizer::new(cfg.vocab_size)?;
    let pred = tk.content_of(tk.until_eos(&rows[0]));
    println!("decoded span prediction (first row, content ids): {pred:?}");
    println!("quickstart OK");
    Ok(())
}
