//! Pretrain -> finetune -> EM/F1 pipeline on one benchmark task —
//! the paper's Sec. 5 recipe end to end at micro scale.
//!
//!     cargo run --release --example finetune_eval -- [--task squad]
//!                [--artifact micro-altup] [--pretrain 150] [--finetune 80]

use altup::coordinator::pipeline::{finetune_task, pretrain, PipelineOptions};
use altup::data::tasks::TaskKind;
use altup::runtime::artifact::load_named;
use altup::runtime::client::Client;
use altup::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let name = args.str_or("artifact", "micro-altup");
    let kind = TaskKind::from_str(&args.str_or("task", "squad"))
        .ok_or_else(|| anyhow::anyhow!("--task glue|superglue|squad|triviaqa"))?;

    let client = Client::cpu()?;
    let opts = PipelineOptions {
        pretrain_steps: args.u64_or("pretrain", 150),
        finetune_steps: args.u64_or("finetune", 80),
        warmup: 1000,
        verbose: true,
        ..Default::default()
    };

    println!("== pretraining {name} for {} steps ==", opts.pretrain_steps);
    let artifact = load_named(&name)?;
    let (session, pre_ev, sps, _data_wait) = pretrain(&client, artifact, &opts)?;
    println!("pretrain done ({sps:.2} steps/s): {}", pre_ev.summary());

    println!("\n== finetuning on {} for {} steps ==", kind.name(), opts.finetune_steps);
    let ev = finetune_task(&client, &session, kind, &opts)?;
    println!("\n{} result: {}", kind.name(), ev.summary());
    if kind.is_generative() {
        println!("(EM/F1 from greedy decode over {} held-out examples)", ev.examples);
    }
    Ok(())
}
