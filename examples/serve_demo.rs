//! Serving demo: the dynamic-batching inference server under a bursty
//! multi-client load, reporting latency percentiles, throughput, and
//! achieved batch fill — the "serving" face of the L3 coordinator.
//!
//!     cargo run --release --example serve_demo -- [--clients 4]
//!                [--requests 32] [--artifact micro-altup]
//!                [--timeout-ms T] [--restarts N] [--spec-gamma G]

use altup::coordinator::server::{ServerHandle, ServerOptions};
use altup::data::tasks::{Task, TaskKind};
use altup::runtime::artifact::load_named;
use altup::util::bench;
use altup::util::cli::Args;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let name = args.str_or("artifact", "micro-altup");
    let clients = args.usize_or("clients", 4);
    let per_client = args.usize_or("requests", 32);
    let replicas = args.usize_or("replicas", 1);

    let artifact = load_named(&name)?;
    let cfg = artifact.config;
    println!(
        "serving {name} (batch {} x enc {}) on {replicas} replica(s), \
         {clients} clients x {per_client} requests",
        cfg.batch_size, cfg.enc_len
    );

    let defaults = ServerOptions::default();
    let server = ServerHandle::spawn(
        &name,
        ServerOptions {
            batch_window: Duration::from_millis(args.u64_or("window-ms", 10)),
            replicas,
            slots: args.usize_or("slots", 0),
            // Compose with the ALTUP_NO_CONT_BATCH env default, same
            // as `altup serve`.
            continuous: !args.has("no-cont") && defaults.continuous,
            // 0 falls through to the ALTUP_REQUEST_TIMEOUT_MS default.
            request_timeout_ms: match args.u64_or("timeout-ms", 0) {
                0 => defaults.request_timeout_ms,
                ms => Some(ms),
            },
            replica_restarts: args.usize_or("restarts", defaults.replica_restarts),
            // §L8: speculative decoding (0 = off; plain-decode
            // fallback when the artifact ships no draft model).
            spec_gamma: args.usize_or("spec-gamma", defaults.spec_gamma),
            ..defaults
        },
    );

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let sender = server.sender.clone();
        let enc_len = cfg.enc_len;
        let vocab = cfg.vocab_size;
        handles.push(std::thread::spawn(move || {
            let task = Task::new(TaskKind::Squad, vocab, c as u64 + 1);
            let mut latencies = Vec::new();
            let mut failed = 0usize;
            for i in 0..per_client {
                let ex = task.example(i as u64, enc_len - 2);
                let (tx, rx) = std::sync::mpsc::channel();
                sender
                    .send(altup::coordinator::server::Request::new(ex.enc, tx))
                    .unwrap();
                // §L7: every admitted request gets a terminal response
                // — tokens, or an explicit failure (deadline shed /
                // retries exhausted).
                let resp = rx.recv().unwrap();
                match resp.failure {
                    Some(_) => failed += 1,
                    None => latencies.push(resp.latency),
                }
            }
            (latencies, failed)
        }));
    }
    let mut all = Vec::new();
    let mut failed = 0usize;
    for h in handles {
        let (lat, f) = h.join().unwrap();
        all.extend(lat);
        failed += f;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    let s = bench::stats_from("request latency", all);

    let total = clients * per_client;
    println!("\n=== serve_demo summary ===");
    println!(
        "throughput:  {:.1} req/s ({total} requests, {failed} failed, in {wall:.2}s)",
        total as f64 / wall
    );
    println!("latency:     {}", s.report());
    println!(
        "batching:    {} batches, mean fill {:.2}/{}",
        stats.batches,
        stats.mean_fill(),
        cfg.batch_size
    );
    println!(
        "serving:     padded waste {:.1}%, latency p50 {:.2} / p95 {:.2} / p99 {:.2} ms",
        stats.waste_ratio() * 100.0,
        stats.p50_ms(),
        stats.p95_ms(),
        stats.p99_ms()
    );
    if stats.decode_steps > 0 {
        println!(
            "decode:      continuous — {} tokens out over {} fused steps, \
             mean occupancy {:.2}, early exit saved {:.1}%, {:.3} ms/token",
            stats.tokens_generated,
            stats.decode_steps,
            stats.occupancy.mean(),
            stats.early_exit_ratio() * 100.0,
            stats.token_ms()
        );
    } else {
        println!(
            "decode:      batch-level — {} tokens out, {:.3} ms/token",
            stats.tokens_generated,
            stats.token_ms()
        );
    }
    if stats.spec.active() {
        println!(
            "speculative: {:.1}% acceptance ({}/{} drafted), {:.2} tokens/verify \
             over {} verify steps ({} draft steps)",
            stats.spec.acceptance_rate() * 100.0,
            stats.spec.accepted,
            stats.spec.drafted,
            stats.spec.tokens_per_verify(),
            stats.spec.verify_steps,
            stats.spec.draft_steps
        );
    }
    println!(
        "lifecycle:   {} shed / {} retried / {} restarts / {} failed / {} drained",
        stats.sheds, stats.retries, stats.restarts, stats.failed, stats.drained
    );
    Ok(())
}
