//! End-to-end driver (DESIGN.md deliverable): pretrain the paper's
//! T5-small-shaped model (~88M params with AltUp K=2, vocab 32128) for a
//! few hundred steps on the synthetic corpus, logging the loss curve to
//! results/e2e_loss.jsonl and a checkpoint to results/e2e.ckpt.
//!
//!     cargo run --release --example pretrain_e2e -- [--steps 200]
//!                [--artifact small-altup] [--resume]
//!
//! The run recorded in EXPERIMENTS.md used the default 200 steps on a
//! single CPU core.

use altup::coordinator::metrics::MetricsLog;
use altup::coordinator::trainer::{DataSource, TrainOptions, Trainer};
use altup::data::batcher::PretrainBatcher;
use altup::runtime::artifact::load_named;
use altup::runtime::client::Client;
use altup::runtime::params::ParamStore;
use altup::runtime::session::Session;
use altup::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let name = args.str_or("artifact", "small-altup");
    let steps = args.u64_or("steps", 200);

    let client = Client::cpu()?;
    let artifact = load_named(&name)?;
    let cfg = artifact.config.clone();
    println!(
        "e2e pretrain: {} — {:.1}M params, batch {}x(enc {} + dec {})",
        name,
        artifact.param_count_total as f64 / 1e6,
        cfg.batch_size,
        cfg.enc_len,
        cfg.dec_len
    );

    let mut session = Session::open(&client, artifact, 0)?;
    std::fs::create_dir_all("results")?;
    let ckpt = format!("results/e2e-{name}.ckpt");
    if args.has("resume") && std::path::Path::new(&ckpt).exists() {
        session.store = ParamStore::load(&ckpt, &session.artifact)?;
        session.invalidate_state();
        println!("resumed from {ckpt} @ step {}", session.store.step);
    }

    let batcher =
        PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 1234);
    let log = MetricsLog::to_file(format!("results/e2e-{name}-loss.jsonl"))?;
    let mut trainer = Trainer::new(session, DataSource::Pretrain(batcher), log);
    let opts = TrainOptions {
        steps,
        warmup: args.u64_or("warmup", 2000),
        base_lr: args.f64_or("lr", 1.0),
        log_every: 10,
        eval_every: args.u64_or("eval-every", 100),
        eval_batches: 4,
        checkpoint_path: Some(ckpt.clone().into()),
        verbose: true,
        constant_lr: None,
        ..Default::default()
    };
    let (ema, sps) = trainer.run(&client, &opts)?;
    trainer.session.checkpoint(&ckpt)?;

    let ev = trainer.eval(&client, 8)?;
    println!("\n=== e2e summary ===");
    println!("steps:        {}", trainer.session.store.step);
    println!("loss (ema):   {ema:.4}");
    println!("val:          {}", ev.summary());
    println!("throughput:   {sps:.3} steps/s ({:.1} tokens/s)",
        sps * cfg.tokens_per_batch() as f64);
    println!(
        "runtime split: execute {:.1}s, marshal {:.1}s, transfer {:.1}s",
        trainer.session.exec_seconds,
        trainer.session.marshal_seconds,
        trainer.session.transfer_seconds
    );
    println!("loss curve:   results/e2e-{name}-loss.jsonl");
    println!("checkpoint:   {ckpt}");
    Ok(())
}
