"""Layer-2: config-driven T5-v1.1-style encoder-decoder in JAX with every
paper variant (baseline / dense-wide / AltUp / SameUp / Sum / Recycled /
Sequence-AltUp / stride-and-skip / average-pooling, each optionally with
partial-experts MoE).

Parameters live in a flat ``{name: array}`` dict; the AOT pipeline
(``aot.py``) serializes the *sorted* name order into ``meta.json`` so the
rust coordinator can initialize/marshal buffers positionally.

Widened variants carry activations as ``(K, B, T, d)`` — leading block
axis — and run the transformer layer on one ``d``-wide block per layer
(Alg. 1). Cross-attention wiring for widened models (underspecified in
the paper): the decoder layer computing block ``j*`` cross-attends to the
encoder's final representation of the *same* block ``j*``; this keeps
every layer at width d and preserves the alternating structure
(DESIGN.md §6).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .configs import Config
from .kernels import grads as kgrad
from .kernels import ref as kref

Params = dict[str, jax.Array]

NEG = -1e9


# ----------------------------------------------------------------------
# Parameter spec + init
# ----------------------------------------------------------------------

class ParamSpec:
    """Shape + init recipe for one parameter (mirrored into meta.json)."""

    def __init__(self, name: str, shape: tuple[int, ...], init: str, scale: float = 1.0):
        self.name = name
        self.shape = shape
        self.init = init  # "normal" | "zeros" | "ones" | "eye"
        self.scale = scale

    def to_dict(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "dtype": "f32",
            "init": self.init,
            "scale": self.scale,
        }

    def instantiate(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, jnp.float32)
        if self.init == "ones":
            return jnp.ones(self.shape, jnp.float32)
        if self.init == "eye":
            assert len(self.shape) == 2 and self.shape[0] == self.shape[1]
            return jnp.eye(self.shape[0], dtype=jnp.float32) * self.scale
        return jax.random.normal(key, self.shape, jnp.float32) * self.scale


def param_specs(cfg: Config) -> list[ParamSpec]:
    """Every parameter of the model, in declaration order."""
    specs: list[ParamSpec] = []
    d = cfg.layer_width
    f = cfg.d_ff * (cfg.k if cfg.variant == "dense_wide" else 1)
    h = cfg.num_heads
    dh = cfg.d_head * (cfg.k if cfg.variant == "dense_wide" else 1)
    inner = h * dh

    def add(name: str, shape: tuple[int, ...], init: str = "normal", scale: float | None = None):
        if scale is None:
            scale = (1.0 / shape[0] ** 0.5) if init == "normal" and len(shape) >= 2 else 1.0
        specs.append(ParamSpec(name, shape, init, scale))

    # Embedding (input table shared between encoder and decoder).
    add("embed/table", (cfg.vocab_size, cfg.embed_width), "normal", 1.0)
    # Output head reads the final representation.
    head_in = cfg.repr_width if cfg.variant != "sum" else cfg.d_model
    if cfg.variant == "recycled":
        head_in = cfg.d_model
    add("head/w", (head_in, cfg.vocab_size))

    # Relative position bias tables (shared across layers, per stack).
    add("enc/relpos", (cfg.rel_pos_buckets, h), "normal", 0.1)
    add("dec/relpos", (cfg.rel_pos_buckets, h), "normal", 0.1)

    def layer(prefix: str, cross: bool):
        add(f"{prefix}/ln_attn", (d,), "ones")
        add(f"{prefix}/attn/q", (d, inner))
        add(f"{prefix}/attn/k", (d, inner))
        add(f"{prefix}/attn/v", (d, inner))
        add(f"{prefix}/attn/o", (inner, d))
        if cross:
            add(f"{prefix}/ln_cross", (d,), "ones")
            add(f"{prefix}/cross/q", (d, inner))
            add(f"{prefix}/cross/k", (d, inner))
            add(f"{prefix}/cross/v", (d, inner))
            add(f"{prefix}/cross/o", (inner, d))
        add(f"{prefix}/ln_ffn", (d,), "ones")
        add(f"{prefix}/ffn/wi0", (d, f))
        add(f"{prefix}/ffn/wi1", (d, f))
        add(f"{prefix}/ffn/wo", (f, d))
        if cfg.moe:
            add(f"{prefix}/moe/router", (d, cfg.moe_experts), "normal", 2e-2)
            add(f"{prefix}/moe/w1", (cfg.moe_experts, d, cfg.moe_hidden))
            add(f"{prefix}/moe/w2", (cfg.moe_experts, cfg.moe_hidden, d))
        if cfg.altup_blocks > 1:
            add(f"{prefix}/altup/p", (cfg.k, cfg.k), "eye")
            add(f"{prefix}/altup/g", (cfg.k,), "ones")
        if cfg.variant == "seq_altup":
            add(f"{prefix}/seqalt/a", (2,), "ones", 0.5)
            add(f"{prefix}/seqalt/b", (1,), "ones")

    for i in range(cfg.enc_layers):
        layer(f"enc/l{i}", cross=False)
    add("enc/ln_final", (d,), "ones")
    for i in range(cfg.dec_layers):
        layer(f"dec/l{i}", cross=True)
    add("dec/ln_final", (d,), "ones")
    return specs


def init_params(cfg: Config, seed: int = 0) -> Params:
    key = jax.random.PRNGKey(seed)
    params: Params = {}
    for spec in param_specs(cfg):
        key, sub = jax.random.split(key)
        params[spec.name] = spec.instantiate(sub)
    return params


# ----------------------------------------------------------------------
# Building blocks
# ----------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _relpos_bucket(rel: jax.Array, num_buckets: int, max_dist: int, bidirectional: bool) -> jax.Array:
    """T5 relative-position bucketing."""
    ret = jnp.zeros_like(rel)
    n = -rel
    if bidirectional:
        num_buckets //= 2
        ret = ret + (n < 0).astype(jnp.int32) * num_buckets
        n = jnp.abs(n)
    else:
        n = jnp.maximum(n, 0)
    max_exact = num_buckets // 2
    is_small = n < max_exact
    val_large = max_exact + (
        jnp.log(n.astype(jnp.float32) / max_exact + 1e-6)
        / jnp.log(max_dist / max_exact)
        * (num_buckets - max_exact)
    ).astype(jnp.int32)
    val_large = jnp.minimum(val_large, num_buckets - 1)
    return ret + jnp.where(is_small, n, val_large)


def relpos_bias(table: jax.Array, tq: int, tk: int, cfg: Config, bidirectional: bool) -> jax.Array:
    """(heads, tq, tk) additive attention bias from a bucket table."""
    rel = jnp.arange(tk)[None, :] - jnp.arange(tq)[:, None]
    buckets = _relpos_bucket(rel, cfg.rel_pos_buckets, cfg.rel_pos_max_dist, bidirectional)
    return jnp.transpose(table[buckets], (2, 0, 1))


def multihead_attention(
    params: Params,
    prefix: str,
    x: jax.Array,
    mem: jax.Array,
    mask: jax.Array,
    cfg: Config,
    bias: jax.Array | None,
) -> jax.Array:
    """x: (B, Tq, d), mem: (B, Tk, d), mask: (B, Tq, Tk) additive."""
    b, tq, d = x.shape
    tk = mem.shape[1]
    h = cfg.num_heads
    dh = (params[f"{prefix}/q"].shape[1]) // h
    q = (x @ params[f"{prefix}/q"]).reshape(b, tq, h, dh)
    k = (mem @ params[f"{prefix}/k"]).reshape(b, tk, h, dh)
    v = (mem @ params[f"{prefix}/v"]).reshape(b, tk, h, dh)
    full_mask = mask[:, None, :, :]
    if bias is not None:
        full_mask = full_mask + bias[None, :, :, :]
    if cfg.kernels == "pallas":
        qh = jnp.transpose(q, (0, 2, 1, 3))
        kh = jnp.transpose(k, (0, 2, 1, 3))
        vh = jnp.transpose(v, (0, 2, 1, 3))
        m = jnp.broadcast_to(full_mask, (b, h, tq, tk))
        out = jax.vmap(jax.vmap(kgrad.flash_attention))(qh, kh, vh, m)
        out = jnp.transpose(out, (0, 2, 1, 3)).reshape(b, tq, h * dh)
    else:
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
        logits = logits + full_mask
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, tq, h * dh)
    return out @ params[f"{prefix}/o"]


def gated_ffn(params: Params, prefix: str, x: jax.Array, cfg: Config) -> jax.Array:
    b, t, d = x.shape
    if cfg.kernels == "pallas":
        y = kgrad.gated_ffn(
            x.reshape(b * t, d),
            params[f"{prefix}/wi0"],
            params[f"{prefix}/wi1"],
            params[f"{prefix}/wo"],
        )
        return y.reshape(b, t, d)
    return kref.gated_ffn_ref(
        x.reshape(b * t, d),
        params[f"{prefix}/wi0"],
        params[f"{prefix}/wi1"],
        params[f"{prefix}/wo"],
    ).reshape(b, t, d)


def moe_partial_experts(params: Params, prefix: str, x: jax.Array) -> jax.Array:
    """Partial-experts MoE (App. C): top-1 softmax routing to small experts.

    Dense dispatch (computes every expert, masks by the routing one-hot);
    at our expert sizes this is cheaper than gather/scatter on CPU and is
    numerically identical to top-1 routing with probability weighting.
    """
    b, t, d = x.shape
    xf = x.reshape(b * t, d)
    logits = xf @ params[f"{prefix}/router"]  # (T, n)
    probs = jax.nn.softmax(logits, axis=-1)
    top = jnp.argmax(probs, axis=-1)  # (T,)
    onehot = jax.nn.one_hot(top, logits.shape[-1], dtype=xf.dtype)
    gate = jnp.sum(probs * onehot, axis=-1, keepdims=True)  # p_i(x) of the top expert
    hidden = jax.nn.relu(jnp.einsum("td,ndh->tnh", xf, params[f"{prefix}/w1"]))
    outs = jnp.einsum("tnh,nhd->tnd", hidden, params[f"{prefix}/w2"])
    y = jnp.einsum("tnd,tn->td", outs, onehot) * gate
    return y.reshape(b, t, d)


def dropout(x: jax.Array, rate: float, seed: jax.Array, salt: int) -> jax.Array:
    if rate <= 0.0:
        return x
    key = jax.random.fold_in(jax.random.PRNGKey(0), seed + jnp.uint32(salt))
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def transformer_layer(
    params: Params,
    prefix: str,
    x: jax.Array,
    self_mask: jax.Array,
    self_bias: jax.Array | None,
    cfg: Config,
    seed: jax.Array,
    salt: int,
    mem: jax.Array | None = None,
    cross_mask: jax.Array | None = None,
) -> jax.Array:
    """One pre-LN transformer layer of width d (the paper's L)."""
    y = rms_norm(x, params[f"{prefix}/ln_attn"])
    y = multihead_attention(params, f"{prefix}/attn", y, y, self_mask, cfg, self_bias)
    x = x + dropout(y, cfg.dropout, seed, salt)
    if mem is not None:
        y = rms_norm(x, params[f"{prefix}/ln_cross"])
        y = multihead_attention(params, f"{prefix}/cross", y, mem, cross_mask, cfg, None)
        x = x + dropout(y, cfg.dropout, seed, salt + 1)
    y = rms_norm(x, params[f"{prefix}/ln_ffn"])
    out = gated_ffn(params, f"{prefix}/ffn", y, cfg)
    if cfg.moe:
        out = out + moe_partial_experts(params, f"{prefix}/moe", y)
    x = x + dropout(out, cfg.dropout, seed, salt + 2)
    return x


# ----------------------------------------------------------------------
# AltUp wrapping (Alg. 1)
# ----------------------------------------------------------------------

def altup_step(
    params: Params,
    prefix: str,
    x: jax.Array,  # (K, B, T, d)
    layer_fn: Callable[[jax.Array], jax.Array],
    jstar: int,
    cfg: Config,
) -> jax.Array:
    """Predict -> compute(L on block j*) -> correct."""
    k, b, t, d = x.shape
    p = params[f"{prefix}/altup/p"]
    g = params[f"{prefix}/altup/g"]
    xtilde = layer_fn(x[jstar])  # (B, T, d)
    if cfg.kernels == "pallas":
        flat = x.reshape(k, b * t, d)
        out = kgrad.altup_predict_correct(flat, xtilde.reshape(b * t, d), p, g, jstar)
        return out.reshape(k, b, t, d)
    xhat = jnp.einsum("ij,jbtd->ibtd", p, x)
    delta = xtilde[None] - xhat[jstar][None]
    return xhat + g[:, None, None, None] * delta


def select_block(layer_idx: int, cfg: Config) -> int:
    """Paper's two deterministic schedules: alternating (default) / same."""
    if cfg.variant == "sameup":
        return 0
    return layer_idx % cfg.k


# ----------------------------------------------------------------------
# Sequence-reduction variants (Sec. 4.2 / Table 2)
# ----------------------------------------------------------------------

def _seq_window(cfg: Config, num_layers: int, layer_idx: int) -> bool:
    """True if sequence reduction applies at this encoder layer."""
    return cfg.seq_first_layer <= layer_idx < num_layers - 1


def seq_reduced_layer(
    params: Params,
    prefix: str,
    x: jax.Array,
    mask_sub: jax.Array,
    bias_sub: jax.Array | None,
    cfg: Config,
    seed: jax.Array,
    salt: int,
) -> jax.Array:
    """Apply L to the strided subsequence; combine per the variant."""
    b, t, d = x.shape
    s = cfg.seq_stride
    xs = x[:, ::s, :]
    layer_out = transformer_layer(
        params, prefix, xs, mask_sub, bias_sub, cfg, seed, salt
    )  # (B, T/s, d)
    if cfg.variant == "stride_skip":
        # Skipped tokens pass through unchanged (Fig. 3 left).
        y = jnp.repeat(layer_out, s, axis=1)
        keep = (jnp.arange(t) % s == 0)[None, :, None]
        return jnp.where(keep, y, x)
    # Sequence-AltUp (Alg. 2).
    a = params[f"{prefix}/seqalt/a"]
    bb = params[f"{prefix}/seqalt/b"]
    if cfg.kernels == "pallas":
        def one(xb, yb):
            yhat = kgrad.seq_altup_predict(xb, a[0], a[1], s)
            return kgrad.seq_altup_correct(yhat, yb, bb[0], s)
        return jax.vmap(one)(x, layer_out)
    anchor = (jnp.arange(t) // s) * s
    yhat = a[0] * x + a[1] * x[:, anchor, :]
    idx = jnp.arange(t) // s
    return yhat + bb[0] * (layer_out[:, idx, :] - yhat[:, anchor, :])


# ----------------------------------------------------------------------
# Encoder / decoder stacks
# ----------------------------------------------------------------------

def _pad_mask(tokens: jax.Array) -> jax.Array:
    """(B, T) bool: True where a real (non-pad) token sits. pad id = 0."""
    return tokens != 0


def _attn_mask(q_valid: jax.Array, k_valid: jax.Array, causal: bool) -> jax.Array:
    """(B, Tq, Tk) additive mask."""
    m = q_valid[:, :, None] & k_valid[:, None, :]
    if causal:
        tq = q_valid.shape[1]
        tk = k_valid.shape[1]
        tri = jnp.tril(jnp.ones((tq, tk), bool))
        m = m & tri[None]
    return jnp.where(m, 0.0, NEG).astype(jnp.float32)


def embed(params: Params, tokens: jax.Array, cfg: Config) -> jax.Array:
    """Token embedding, shaped per variant.

    Returns (K, B, T, d) for block variants, else (B, T, width).
    """
    e = params["embed/table"][tokens]  # (B, T, embed_width)
    b, t, _ = e.shape
    if cfg.variant in ("altup", "sameup"):
        return jnp.transpose(e.reshape(b, t, cfg.k, cfg.d_model), (2, 0, 1, 3))
    if cfg.variant == "recycled":
        # Recycle: replicate the d-wide lookup K times (Fig. 2).
        return jnp.broadcast_to(e[None], (cfg.k, b, t, cfg.d_model))
    if cfg.variant == "sum":
        return jnp.sum(e.reshape(b, t, cfg.k, cfg.d_model), axis=2)
    return e


def encode(params: Params, enc_tokens: jax.Array, cfg: Config, seed: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (memory, enc_valid). memory is (K,B,T,d) or (B,T,d)."""
    valid = _pad_mask(enc_tokens)
    x = embed(params, enc_tokens, cfg)
    wide = cfg.altup_blocks > 1
    nl = cfg.enc_layers
    if cfg.variant == "avg_pool":
        s = cfg.seq_stride
        b, t, d = x.shape
        xg = x.reshape(b, t // s, s, d)
        vg = valid.reshape(b, t // s, s)
        cnt = jnp.maximum(jnp.sum(vg, axis=-1, keepdims=True), 1).astype(x.dtype)
        x = jnp.sum(xg * vg[..., None], axis=2) / cnt
        valid = jnp.any(vg, axis=-1)

    t_full = x.shape[-2]
    bias_full = relpos_bias(params["enc/relpos"], t_full, t_full, cfg, True)
    mask_full = _attn_mask(valid, valid, causal=False)
    if cfg.variant in ("seq_altup", "stride_skip"):
        s = cfg.seq_stride
        valid_sub = valid[:, ::s]
        mask_sub = _attn_mask(valid_sub, valid_sub, causal=False)
        ts = t_full // s
        rel = (jnp.arange(ts)[None, :] - jnp.arange(ts)[:, None]) * s
        buckets = _relpos_bucket(rel, cfg.rel_pos_buckets, cfg.rel_pos_max_dist, True)
        bias_sub = jnp.transpose(params["enc/relpos"][buckets], (2, 0, 1))

    for i in range(nl):
        prefix = f"enc/l{i}"
        if wide:
            fn = functools.partial(
                transformer_layer, params, prefix,
                self_mask=mask_full, self_bias=bias_full, cfg=cfg,
                seed=seed, salt=1000 + 10 * i,
            )
            x = altup_step(params, prefix, x, lambda blk: fn(blk), select_block(i, cfg), cfg)
        elif cfg.variant in ("seq_altup", "stride_skip") and _seq_window(cfg, nl, i):
            x = seq_reduced_layer(params, prefix, x, mask_sub, bias_sub, cfg, seed, 1000 + 10 * i)
        else:
            x = transformer_layer(
                params, prefix, x, mask_full, bias_full, cfg, seed, 1000 + 10 * i
            )
    x = rms_norm(x, params["enc/ln_final"])
    return x, valid


def decode(
    params: Params,
    memory: jax.Array,
    enc_valid: jax.Array,
    dec_tokens: jax.Array,
    cfg: Config,
    seed: jax.Array,
) -> jax.Array:
    """Decoder stack -> logits (B, Td, vocab)."""
    valid = _pad_mask(dec_tokens) | (jnp.arange(dec_tokens.shape[1]) == 0)[None]
    x = embed(params, dec_tokens, cfg)
    wide = cfg.altup_blocks > 1
    td = dec_tokens.shape[1]
    bias = relpos_bias(params["dec/relpos"], td, td, cfg, False)
    self_mask = _attn_mask(valid, valid, causal=True)
    cross_mask = _attn_mask(valid, enc_valid, causal=False)

    for i in range(cfg.dec_layers):
        prefix = f"dec/l{i}"
        if wide:
            jstar = select_block(cfg.enc_layers + i, cfg)
            mem_blk = memory[jstar]
            fn = functools.partial(
                transformer_layer, params, prefix,
                self_mask=self_mask, self_bias=bias, cfg=cfg,
                seed=seed, salt=2000 + 10 * i,
                mem=mem_blk, cross_mask=cross_mask,
            )
            x = altup_step(params, prefix, x, lambda blk: fn(blk), jstar, cfg)
        else:
            mem = memory
            x = transformer_layer(
                params, prefix, x, self_mask, bias, cfg, seed, 2000 + 10 * i,
                mem=mem, cross_mask=cross_mask,
            )
    x = rms_norm(x, params["dec/ln_final"])

    # Output head.
    if wide:
        k, b, t, d = x.shape
        if cfg.variant == "recycled":
            if cfg.kernels == "pallas":
                flat = kgrad.recycled_downproject(x.reshape(k, b * t, d))
                x = flat.reshape(b, t, d)
            else:
                x = jnp.sum(x, axis=0)
        else:
            x = jnp.transpose(x, (1, 2, 0, 3)).reshape(b, t, k * d)
    return x @ params["head/w"]


def forward(
    params: Params,
    enc_tokens: jax.Array,
    dec_tokens: jax.Array,
    cfg: Config,
    seed: jax.Array | None = None,
) -> jax.Array:
    """Full model: token ids -> logits (B, Td, vocab)."""
    if seed is None:
        seed = jnp.uint32(0)
    memory, enc_valid = encode(params, enc_tokens, cfg, seed)
    return decode(params, memory, enc_valid, dec_tokens, cfg, seed)


# ----------------------------------------------------------------------
# Loss / metrics
# ----------------------------------------------------------------------

def loss_and_metrics(
    logits: jax.Array, targets: jax.Array, label_smoothing: float = 0.0
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Cross-entropy over non-pad targets.

    Returns (mean_loss, num_correct, num_tokens) — the latter two as f32
    sums so they aggregate across batches on the rust side.
    """
    vocab = logits.shape[-1]
    mask = (targets != 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    if label_smoothing > 0.0:
        onehot = jax.nn.one_hot(targets, vocab)
        soft = onehot * (1 - label_smoothing) + label_smoothing / vocab
        nll = -jnp.sum(soft * logp, axis=-1)
    else:
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ntok = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / ntok
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == targets).astype(jnp.float32) * mask)
    return loss, correct, jnp.sum(mask)


# ----------------------------------------------------------------------
# Greedy decode (for EM/F1 finetune metrics)
# ----------------------------------------------------------------------

def greedy_decode(
    params: Params, enc_tokens: jax.Array, cfg: Config
) -> jax.Array:
    """Greedy autoregressive decode of cfg.dec_len tokens.

    Naive full-recompute per position (no KV cache): exactly the
    numerics of incremental decoding, acceptable at testbed scale. The
    rust server batches requests into (B, enc_len) calls of this
    executable.
    """
    b = enc_tokens.shape[0]
    memory, enc_valid = encode(params, enc_tokens, cfg, jnp.uint32(0))
    dec = jnp.zeros((b, cfg.dec_len), jnp.int32)  # BOS = pad id 0

    def body(t, dec):
        logits = decode(params, memory, enc_valid, dec, cfg, jnp.uint32(0))
        nxt = jnp.argmax(logits[:, t, :], axis=-1).astype(jnp.int32)
        return jax.lax.cond(
            t + 1 < cfg.dec_len,
            lambda d: jax.lax.dynamic_update_slice(d, nxt[:, None], (0, t + 1)),
            lambda d: d,
            dec,
        )

    dec = jax.lax.fori_loop(0, cfg.dec_len, body, dec)
    # Shift left: position t holds the token predicted *at* t.
    logits = decode(params, memory, enc_valid, dec, cfg, jnp.uint32(0))
    preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return preds


# ----------------------------------------------------------------------
# Analytic accounting (mirrored in rust/src/model/counting.rs)
# ----------------------------------------------------------------------

def count_params(cfg: Config) -> dict[str, int]:
    emb = 0
    non_emb = 0
    for spec in param_specs(cfg):
        n = 1
        for s in spec.shape:
            n *= s
        if spec.name.startswith(("embed/", "head/")):
            emb += n
        else:
            non_emb += n
    return {"embedding": emb, "non_embedding": non_emb, "total": emb + non_emb}


def flops_per_token(cfg: Config) -> float:
    """Rough forward FLOPs per (encoder) token — for the roofline model."""
    d = cfg.layer_width
    f = cfg.d_ff * (cfg.k if cfg.variant == "dense_wide" else 1)
    inner = cfg.num_heads * cfg.d_head * (cfg.k if cfg.variant == "dense_wide" else 1)
    n = cfg.enc_len
    attn = 2 * (4 * d * inner) + 2 * 2 * n * inner
    ffn = 2 * 3 * d * f
    per_layer = attn + ffn
    if cfg.altup_blocks > 1:
        per_layer += 2 * d * (cfg.k * cfg.k + cfg.k)  # predict+correct vector work
    layers = cfg.enc_layers + cfg.dec_layers
    return per_layer * layers
