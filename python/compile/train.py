"""Layer-2 training step: loss, Adafactor, and the flat-signature
``train_step`` / ``eval_step`` functions that get AOT-lowered.

Adafactor follows Shazeer & Stern (2018) as used by T5X: factored second
moments for matrices, update clipping at RMS 1.0, parameter-RMS scaling,
``beta2_t = 1 - t^-0.8``, no momentum. The learning-rate schedule
(reciprocal square-root with warmup, base LR 1.0 — the paper's recipe)
lives on the *host* (rust coordinator) and is passed in as a scalar, so
schedule changes never require re-lowering.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import model as M
from .configs import Config

EPS1 = 1e-30
EPS2 = 1e-3
CLIP = 1.0


def _factored(shape: tuple[int, ...]) -> bool:
    return len(shape) == 2 and min(shape) >= 8


def opt_state_specs(cfg: Config) -> list[dict[str, Any]]:
    """Flat opt-state slots, aligned with sorted param order."""
    slots: list[dict[str, Any]] = []
    for spec in sorted(M.param_specs(cfg), key=lambda s: s.name):
        shape = tuple(spec.shape)
        if _factored(shape):
            slots.append({"name": f"{spec.name}@vr", "shape": [shape[0]], "dtype": "f32"})
            slots.append({"name": f"{spec.name}@vc", "shape": [shape[1]], "dtype": "f32"})
        else:
            slots.append({"name": f"{spec.name}@v", "shape": list(shape), "dtype": "f32"})
    return slots


def init_opt_state(params: M.Params) -> dict[str, jax.Array]:
    state: dict[str, jax.Array] = {}
    for name in sorted(params):
        shape = params[name].shape
        if _factored(shape):
            state[f"{name}@vr"] = jnp.zeros((shape[0],), jnp.float32)
            state[f"{name}@vc"] = jnp.zeros((shape[1],), jnp.float32)
        else:
            state[f"{name}@v"] = jnp.zeros(shape, jnp.float32)
    return state


def _rms(x: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def adafactor_update(
    param: jax.Array,
    grad: jax.Array,
    state: dict[str, jax.Array],
    name: str,
    step: jax.Array,
    lr: jax.Array,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One Adafactor update; returns (new_param, new_state_slots)."""
    beta2 = 1.0 - jnp.power(step, -0.8)
    g2 = jnp.square(grad) + EPS1
    if _factored(param.shape):
        vr = beta2 * state[f"{name}@vr"] + (1 - beta2) * jnp.mean(g2, axis=1)
        vc = beta2 * state[f"{name}@vc"] + (1 - beta2) * jnp.mean(g2, axis=0)
        denom = jnp.maximum(jnp.mean(vr), EPS1)
        vhat = (vr[:, None] * vc[None, :]) / denom
        u = grad * jax.lax.rsqrt(vhat + EPS1)
        new_state = {f"{name}@vr": vr, f"{name}@vc": vc}
    else:
        v = beta2 * state[f"{name}@v"] + (1 - beta2) * g2
        u = grad * jax.lax.rsqrt(v + EPS1)
        new_state = {f"{name}@v": v}
    u = u / jnp.maximum(1.0, _rms(u) / CLIP)
    scale = jnp.maximum(EPS2, _rms(param))
    return param - lr * scale * u, new_state


# ----------------------------------------------------------------------
# Flat-signature step functions (AOT surface)
# ----------------------------------------------------------------------

def param_order(cfg: Config) -> list[str]:
    return sorted(s.name for s in M.param_specs(cfg))


def opt_order(cfg: Config) -> list[str]:
    return [s["name"] for s in opt_state_specs(cfg)]


def make_train_step(cfg: Config):
    """Returns fn(*params, *opt, step, lr, seed, enc, dec_in, dec_tgt)
    -> (*new_params, *new_opt, loss, correct, ntok)."""
    pnames = param_order(cfg)
    onames = opt_order(cfg)
    np_, no_ = len(pnames), len(onames)

    def train_step(*args):
        params = dict(zip(pnames, args[:np_]))
        opt = dict(zip(onames, args[np_:np_ + no_]))
        step, lr, seed, enc, dec_in, dec_tgt = args[np_ + no_:]

        def loss_fn(p):
            logits = M.forward(p, enc, dec_in, cfg, seed=seed)
            loss, correct, ntok = M.loss_and_metrics(
                logits, dec_tgt, cfg.label_smoothing
            )
            return loss, (correct, ntok)

        (loss, (correct, ntok)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params: dict[str, jax.Array] = {}
        new_opt: dict[str, jax.Array] = {}
        for name in pnames:
            newp, slots = adafactor_update(
                params[name], grads[name], opt, name, step, lr
            )
            new_params[name] = newp
            new_opt.update(slots)
        outs = [new_params[n] for n in pnames]
        outs += [new_opt[n] for n in onames]
        outs += [loss, correct, ntok]
        return tuple(outs)

    return train_step


def make_eval_step(cfg: Config):
    """fn(*params, enc, dec_in, dec_tgt) -> (loss_sum, correct, ntok).

    Teacher-forced; sums (not means) so batches aggregate exactly.
    """
    pnames = param_order(cfg)
    np_ = len(pnames)

    def eval_step(*args):
        params = dict(zip(pnames, args[:np_]))
        enc, dec_in, dec_tgt = args[np_:]
        logits = M.forward(params, enc, dec_in, cfg)
        loss, correct, ntok = M.loss_and_metrics(logits, dec_tgt)
        return (loss * ntok, correct, ntok)

    return eval_step


def make_decode_step(cfg: Config):
    """fn(*params, enc) -> (B, dec_len) greedy token ids."""
    pnames = param_order(cfg)
    np_ = len(pnames)

    def decode_step(*args):
        params = dict(zip(pnames, args[:np_]))
        (enc,) = args[np_:]
        return (M.greedy_decode(params, enc, cfg),)

    return decode_step


def make_forward(cfg: Config):
    """fn(*params, enc, dec_in) -> logits — latency-bench surface."""
    pnames = param_order(cfg)
    np_ = len(pnames)

    def fwd(*args):
        params = dict(zip(pnames, args[:np_]))
        enc, dec_in = args[np_:]
        return (M.forward(params, enc, dec_in, cfg),)

    return fwd


def lr_schedule(step: int, warmup: int = 10_000, base: float = 1.0) -> float:
    """Reciprocal square-root decay with warmup (paper Sec. A).

    Host-side reference implementation; the rust coordinator mirrors it.
    """
    return base / max(step, warmup) ** 0.5
