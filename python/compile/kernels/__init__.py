"""Layer-1 Pallas kernels (interpret=True on CPU) and their jnp oracles."""

from . import altup, attention, ffn, ref, seq_altup  # noqa: F401
