"""Pallas kernels for the AltUp predict/compute/correct steps.

Hardware adaptation (paper -> TPU -> this CPU testbed): the AltUp
predict/correct math is pure vector work — ``O(d * K^2)`` per token, no
matmuls large enough to engage the MXU. On a real TPU the natural
schedule streams ``(bt, d)`` row-tiles of each of the K blocks from HBM
into VMEM, applies the K x K scalar mixture on the VPU, and streams the
result back; the BlockSpecs below express exactly that HBM<->VMEM
schedule. On this testbed the kernels run under ``interpret=True``
(Mosaic custom-calls cannot execute on the CPU PJRT plugin), so we
validate structure + numerics here and estimate VMEM/roofline in
``rust/src/sim`` (see DESIGN.md).

All kernels operate on ``(K, T, d)`` activations where ``T`` is a
flattened ``batch * seq`` dimension and ``K`` is the AltUp expansion
factor (typically 2 or 4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_block(t: int, bt: int) -> int:
    """Largest block size <= bt that divides t."""
    bt = min(bt, t)
    while t % bt != 0:
        bt -= 1
    return bt


def _predict_kernel(p_ref, x_ref, o_ref, *, k: int):
    """o[i, :, :] = sum_j p[i, j] * x[j, :, :] for one (bt, d) row tile.

    VMEM footprint per grid step: (K * bt * d) in + (K * bt * d) out
    + K*K scalars — double-buffered on TPU this is 2*(2*K*bt*d + K*K)
    floats.
    """
    x = x_ref[...]  # (k, bt, d)
    p = p_ref[...]  # (k, k)
    # K is tiny (2 or 4): unrolled scalar-vector mixture; stays on the VPU.
    for i in range(k):
        acc = p[i, 0] * x[0]
        for j in range(1, k):
            acc = acc + p[i, j] * x[j]
        o_ref[i, :, :] = acc


def altup_predict(x: jax.Array, p: jax.Array, *, block_rows: int = 256) -> jax.Array:
    """Pallas AltUp predict: x (K, T, d), p (K, K) -> (K, T, d)."""
    k, t, d = x.shape
    assert p.shape == (k, k), (p.shape, k)
    bt = _row_block(t, block_rows)
    grid = (t // bt,)
    return pl.pallas_call(
        functools.partial(_predict_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, k), lambda r: (0, 0)),
            pl.BlockSpec((k, bt, d), lambda r: (0, r, 0)),
        ],
        out_specs=pl.BlockSpec((k, bt, d), lambda r: (0, r, 0)),
        out_shape=jax.ShapeDtypeStruct((k, t, d), x.dtype),
        interpret=True,
    )(p, x)


def _correct_kernel(g_ref, xhat_ref, xtilde_ref, o_ref, *, k: int, jstar: int):
    """o[i] = xhat[i] + g[i] * (xtilde - xhat[jstar]) for one row tile."""
    xhat = xhat_ref[...]  # (k, bt, d)
    delta = xtilde_ref[...][0] - xhat[jstar]  # (bt, d)
    g = g_ref[...]
    for i in range(k):
        o_ref[i, :, :] = xhat[i] + g[i] * delta


def altup_correct(
    xhat: jax.Array,
    xtilde: jax.Array,
    g: jax.Array,
    jstar: int,
    *,
    block_rows: int = 256,
) -> jax.Array:
    """Pallas AltUp correct: xhat (K, T, d), xtilde (T, d), g (K,) -> (K, T, d).

    ``jstar`` is static: block selection is a compile-time schedule
    (alternating or same), exactly as in the paper.
    """
    k, t, d = xhat.shape
    assert xtilde.shape == (t, d)
    assert g.shape == (k,)
    assert 0 <= jstar < k
    bt = _row_block(t, block_rows)
    grid = (t // bt,)
    return pl.pallas_call(
        functools.partial(_correct_kernel, k=k, jstar=jstar),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda r: (0,)),
            pl.BlockSpec((k, bt, d), lambda r: (0, r, 0)),
            pl.BlockSpec((1, bt, d), lambda r: (0, r, 0)),
        ],
        out_specs=pl.BlockSpec((k, bt, d), lambda r: (0, r, 0)),
        out_shape=jax.ShapeDtypeStruct((k, t, d), xhat.dtype),
        interpret=True,
    )(g, xhat, xtilde[None])


def _predict_correct_kernel(
    p_ref, g_ref, x_ref, xtilde_ref, o_ref, *, k: int, jstar: int
):
    """Fused predict+correct: one pass over the row tile.

    Reads each x[j] tile once and never materializes xhat in HBM —
    this is the §Perf-optimized form (halves HBM traffic vs running
    predict and correct as separate kernels).
    """
    x = x_ref[...]  # (k, bt, d)
    p = p_ref[...]
    g = g_ref[...]
    xhat_jstar = p[jstar, 0] * x[0]
    for j in range(1, k):
        xhat_jstar = xhat_jstar + p[jstar, j] * x[j]
    delta = xtilde_ref[...][0] - xhat_jstar
    for i in range(k):
        acc = p[i, 0] * x[0]
        for j in range(1, k):
            acc = acc + p[i, j] * x[j]
        o_ref[i, :, :] = acc + g[i] * delta


def altup_predict_correct(
    x: jax.Array,
    xtilde: jax.Array,
    p: jax.Array,
    g: jax.Array,
    jstar: int,
    *,
    block_rows: int = 256,
) -> jax.Array:
    """Fused AltUp predict+correct (given the computed block's output).

    Note: the *compute* step (the transformer layer itself) happens
    between predict and correct in Alg. 1, but only the j* prediction
    feeds the correction, so predict-for-i!=j* commutes past the layer
    and the two steps fuse into one kernel around it.
    """
    k, t, d = x.shape
    bt = _row_block(t, block_rows)
    grid = (t // bt,)
    return pl.pallas_call(
        functools.partial(_predict_correct_kernel, k=k, jstar=jstar),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, k), lambda r: (0, 0)),
            pl.BlockSpec((k,), lambda r: (0,)),
            pl.BlockSpec((k, bt, d), lambda r: (0, r, 0)),
            pl.BlockSpec((1, bt, d), lambda r: (0, r, 0)),
        ],
        out_specs=pl.BlockSpec((k, bt, d), lambda r: (0, r, 0)),
        out_shape=jax.ShapeDtypeStruct((k, t, d), x.dtype),
        interpret=True,
    )(p, g, x, xtilde[None])


def _downproject_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...]
    acc = x[0]
    for i in range(1, k):
        acc = acc + x[i]
    o_ref[...] = acc


def recycled_downproject(x: jax.Array, *, block_rows: int = 256) -> jax.Array:
    """Recycled-AltUp down-projection: (K, T, d) -> (T, d) block sum."""
    k, t, d = x.shape
    bt = _row_block(t, block_rows)
    return pl.pallas_call(
        functools.partial(_downproject_kernel, k=k),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((k, bt, d), lambda r: (0, r, 0))],
        out_specs=pl.BlockSpec((bt, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x)
