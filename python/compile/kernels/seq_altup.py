"""Pallas kernels for Sequence-AltUp (Alg. 2): predict/correct along the
sequence axis with stride k.

The row-tile size is forced to a multiple of the stride so every token's
anchor ``floor(i/k)*k`` lives in the same VMEM tile — the kernel then
needs no cross-tile gathers (the TPU-friendly layout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(t: int, bt: int, stride: int) -> int:
    """Largest multiple of stride <= bt that divides t (t % stride == 0)."""
    assert t % stride == 0, (t, stride)
    bt = max(stride, (min(bt, t) // stride) * stride)
    while t % bt != 0:
        bt -= stride
    return bt


def _predict_kernel(ab_ref, x_ref, o_ref, *, stride: int):
    x = x_ref[...]  # (bt, d)
    bt, d = x.shape
    a1 = ab_ref[0]
    a2 = ab_ref[1]
    # Anchor of token i within the tile: (i // stride) * stride. Realized
    # as a reshape to (bt/stride, stride, d) and a broadcast of lane 0.
    xg = x.reshape(bt // stride, stride, d)
    anchors = jnp.broadcast_to(xg[:, :1, :], xg.shape).reshape(bt, d)
    o_ref[...] = a1 * x + a2 * anchors


def seq_altup_predict(
    x: jax.Array, a1: jax.Array, a2: jax.Array, stride: int, *, block_rows: int = 256
) -> jax.Array:
    """yhat_i = a1 * x_i + a2 * x_{floor(i/stride)*stride}; x: (T, d)."""
    t, d = x.shape
    bt = _tile(t, block_rows, stride)
    ab = jnp.stack([a1.astype(x.dtype), a2.astype(x.dtype)])
    return pl.pallas_call(
        functools.partial(_predict_kernel, stride=stride),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((2,), lambda r: (0,)),
            pl.BlockSpec((bt, d), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(ab, x)


def _correct_kernel(b_ref, yhat_ref, ytilde_ref, o_ref, *, stride: int):
    yhat = yhat_ref[...]  # (bt, d)
    ytilde = ytilde_ref[...]  # (bt/stride, d)
    bt, d = yhat.shape
    b = b_ref[0]
    yg = yhat.reshape(bt // stride, stride, d)
    anchors = jnp.broadcast_to(yg[:, :1, :], yg.shape).reshape(bt, d)
    ytile = jnp.broadcast_to(ytilde[:, None, :], yg.shape).reshape(bt, d)
    o_ref[...] = yhat + b * (ytile - anchors)


def seq_altup_correct(
    yhat: jax.Array,
    ytilde: jax.Array,
    b: jax.Array,
    stride: int,
    *,
    block_rows: int = 256,
) -> jax.Array:
    """y_i = yhat_i + b*(ytilde_{i//k} - yhat_{floor(i/k)*k}).

    yhat: (T, d) with T % stride == 0; ytilde: (T/stride, d).
    """
    t, d = yhat.shape
    assert ytilde.shape == (t // stride, d), (ytilde.shape, t, stride)
    bt = _tile(t, block_rows, stride)
    return pl.pallas_call(
        functools.partial(_correct_kernel, stride=stride),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((1,), lambda r: (0,)),
            pl.BlockSpec((bt, d), lambda r: (r, 0)),
            pl.BlockSpec((bt // stride, d), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), yhat.dtype),
        interpret=True,
    )(b.reshape(1).astype(yhat.dtype), yhat, ytilde)
