"""Pallas kernel for the T5-v1.1 gated-GELU feed-forward block.

TPU mapping: the FFN is the MXU workload. The schedule tiles rows of the
activation into ``(bt, d)`` VMEM blocks and the hidden dimension into
``(d, bf)`` weight panels; for each row tile the kernel accumulates the
output in a VMEM scratch block while streaming hidden panels, i.e. the
classic "weights-stationary-per-panel" software pipeline the paper's
baseline T5 uses. VMEM per step = bt*d (x) + 2*d*bf (wi panels) + bt*bf
(h) + f/bf-accumulated bt*d (out) floats.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, wi0_ref, wi1_ref, wo_ref, o_ref, *, nbf: int):
    """Grid = (rows, hidden-panels). Accumulates into o_ref across panels."""
    f_idx = pl.program_id(1)
    x = x_ref[...]  # (bt, d)
    h = jax.nn.gelu(x @ wi0_ref[...], approximate=True) * (x @ wi1_ref[...])
    contrib = h @ wo_ref[...]  # (bt, d)

    @pl.when(f_idx == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(f_idx != 0)
    def _acc():
        o_ref[...] = o_ref[...] + contrib


def _block(n: int, b: int) -> int:
    b = min(b, n)
    while n % b != 0:
        b -= 1
    return b


def gated_ffn(
    x: jax.Array,
    wi0: jax.Array,
    wi1: jax.Array,
    wo: jax.Array,
    *,
    block_rows: int = 128,
    block_hidden: int = 512,
) -> jax.Array:
    """y = (gelu(x @ wi0) * (x @ wi1)) @ wo with row/hidden tiling.

    x: (T, d); wi0, wi1: (d, f); wo: (f, d) -> (T, d).
    """
    t, d = x.shape
    f = wi0.shape[1]
    assert wi0.shape == (d, f) and wi1.shape == (d, f) and wo.shape == (f, d)
    bt = _block(t, block_rows)
    bf = _block(f, block_hidden)
    grid = (t // bt, f // bf)
    return pl.pallas_call(
        functools.partial(_ffn_kernel, nbf=f // bf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda r, c: (r, 0)),
            pl.BlockSpec((d, bf), lambda r, c: (0, c)),
            pl.BlockSpec((d, bf), lambda r, c: (0, c)),
            pl.BlockSpec((bf, d), lambda r, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda r, c: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, wi0, wi1, wo)
