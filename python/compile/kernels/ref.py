"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these to float tolerance. The L2 model can also be
configured to run entirely on these references (``kernels="jnp"``), which
is what the latency-oriented artifacts use (interpret-mode Pallas blocks
XLA fusion on CPU; see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def altup_predict_ref(x: jax.Array, p: jax.Array) -> jax.Array:
    """AltUp predict step (Alg. 1, line 1).

    Args:
      x: ``(K, T, d)`` — the K sub-blocks of the widened representation
         (T is any flattened batch*sequence dimension).
      p: ``(K, K)`` — trainable mixing scalars ``p[i, j]``.

    Returns:
      ``(K, T, d)`` — predictions ``xhat[i] = sum_j p[i, j] * x[j]``.
    """
    return jnp.einsum("ij,jtd->itd", p, x)


def altup_correct_ref(
    xhat: jax.Array, xtilde: jax.Array, g: jax.Array, jstar: int
) -> jax.Array:
    """AltUp correct step (Alg. 1, line 3).

    Args:
      xhat: ``(K, T, d)`` predictions from the predict step.
      xtilde: ``(T, d)`` the computed (layer-transformed) block ``j*``.
      g: ``(K,)`` trainable correction gains.
      jstar: static index of the computed block.

    Returns:
      ``(K, T, d)`` — ``xnew[i] = xhat[i] + g[i] * (xtilde - xhat[jstar])``.
    """
    delta = xtilde[None, :, :] - xhat[jstar][None, :, :]
    return xhat + g[:, None, None] * delta


def gated_ffn_ref(
    x: jax.Array, wi0: jax.Array, wi1: jax.Array, wo: jax.Array
) -> jax.Array:
    """T5-v1.1 gated-GELU feed-forward block.

    ``y = (gelu(x @ wi0) * (x @ wi1)) @ wo`` with x: (T, d),
    wi0/wi1: (d, f), wo: (f, d).
    """
    h = jax.nn.gelu(x @ wi0, approximate=True) * (x @ wi1)
    return h @ wo


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None
) -> jax.Array:
    """Single-head scaled dot-product attention.

    q: (Tq, dh), k/v: (Tk, dh), mask: (Tq, Tk) additive (0 / -inf-ish)
    or None. Returns (Tq, dh).
    """
    dh = q.shape[-1]
    logits = (q @ k.T) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    if mask is not None:
        logits = logits + mask
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return w @ v


def seq_altup_predict_ref(
    x: jax.Array, a1: jax.Array, a2: jax.Array, stride: int
) -> jax.Array:
    """Sequence-AltUp predict (Alg. 2, line 1).

    x: (T, d). ``yhat_i = a1 * x_i + a2 * x_{floor(i/k)*k}``.
    """
    t = x.shape[0]
    anchor = (jnp.arange(t) // stride) * stride
    return a1 * x + a2 * x[anchor]


def seq_altup_correct_ref(
    yhat: jax.Array, ytilde: jax.Array, b: jax.Array, stride: int
) -> jax.Array:
    """Sequence-AltUp correct (Alg. 2, line 3).

    yhat: (T, d) predictions; ytilde: (ceil(T/k), d) outputs of the layer
    on the strided subsequence; ``y_i = yhat_i + b * (ytilde_{i//k} -
    yhat_{floor(i/k)*k})``.
    """
    t = yhat.shape[0]
    idx = jnp.arange(t) // stride
    anchor = idx * stride
    return yhat + b * (ytilde[idx] - yhat[anchor])


def recycled_downproject_ref(x: jax.Array) -> jax.Array:
    """Recycled-AltUp output down-projection: elementwise block sum.

    x: (K, T, d) -> (T, d).
    """
    return jnp.sum(x, axis=0)
