"""custom_vjp wrappers for the Pallas kernels.

Pallas calls (like any hand-written fused kernel) do not get reverse-mode
AD for free. Each forward kernel is paired with a backward derived from
its pure-jnp oracle via ``jax.vjp`` — mathematically exact, and the
oracle itself XLA-fuses on the backward pass. This is the same contract
FlashAttention et al. use: custom forward schedule, analytically-derived
backward.

The wrappers are what ``model.py`` calls when ``cfg.kernels ==
"pallas"``, making the full train step differentiable end-to-end through
the L1 kernels.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax

from . import altup as kaltup
from . import attention as kattn
from . import ffn as kffn
from . import ref as kref
from . import seq_altup as kseq


def _with_ref_vjp(
    pallas_fn: Callable, ref_fn: Callable, ndiff: int, nstatic: int = 0
) -> Callable:
    """Pair a Pallas forward with a ref-derived backward.

    Args are ``(*diff_arrays[ndiff], *static[nstatic])``; statics must be
    hashable (they select the compiled kernel, e.g. jstar or stride).
    """
    if nstatic == 0:

        @jax.custom_vjp
        def wrapped(*args):
            return pallas_fn(*args)

        def fwd(*args):
            return pallas_fn(*args), args

        def bwd(residuals, ct):
            _, vjp = jax.vjp(ref_fn, *residuals)
            return vjp(ct)

    else:
        statics = tuple(range(ndiff, ndiff + nstatic))

        @functools.partial(jax.custom_vjp, nondiff_argnums=statics)
        def wrapped(*args):
            return pallas_fn(*args)

        def fwd(*args):
            return pallas_fn(*args), args[:ndiff]

        def bwd(*args):
            static = args[:nstatic]
            residuals, ct = args[nstatic], args[nstatic + 1]
            _, vjp = jax.vjp(lambda *xs: ref_fn(*xs, *static), *residuals)
            return vjp(ct)

    wrapped.defvjp(fwd, bwd)
    return wrapped


def _pc_ref(x, xtilde, p, g, jstar):
    xhat = kref.altup_predict_ref(x, p)
    return kref.altup_correct_ref(xhat, xtilde, g, jstar)


# (x, xtilde, p, g | jstar)
altup_predict_correct = _with_ref_vjp(
    lambda x, xt, p, g, jstar: kaltup.altup_predict_correct(x, xt, p, g, jstar),
    _pc_ref,
    ndiff=4,
    nstatic=1,
)

# (x, p)
altup_predict = _with_ref_vjp(
    lambda x, p: kaltup.altup_predict(x, p), kref.altup_predict_ref, ndiff=2
)

# (x,)
recycled_downproject = _with_ref_vjp(
    lambda x: kaltup.recycled_downproject(x), kref.recycled_downproject_ref, ndiff=1
)

# (x, wi0, wi1, wo)
gated_ffn = _with_ref_vjp(
    lambda x, wi0, wi1, wo: kffn.gated_ffn(x, wi0, wi1, wo),
    kref.gated_ffn_ref,
    ndiff=4,
)

# (q, k, v, mask)
flash_attention = _with_ref_vjp(
    lambda q, k, v, mask: kattn.flash_attention(q, k, v, mask),
    kref.attention_ref,
    ndiff=4,
)

# (x, a1, a2 | stride)
seq_altup_predict = _with_ref_vjp(
    lambda x, a1, a2, stride: kseq.seq_altup_predict(x, a1, a2, stride),
    kref.seq_altup_predict_ref,
    ndiff=3,
    nstatic=1,
)

# (yhat, ytilde, b | stride)
seq_altup_correct = _with_ref_vjp(
    lambda yhat, yt, b, stride: kseq.seq_altup_correct(yhat, yt, b, stride),
    kref.seq_altup_correct_ref,
    ndiff=3,
    nstatic=1,
)
