"""Pallas flash-style attention kernel (single head, online softmax).

TPU mapping: grid over query row tiles; for each (bq, dh) query tile the
kernel streams (bk, dh) key/value tiles through VMEM, maintaining the
running max / normalizer / weighted accumulator of the online-softmax
recurrence. This is the standard FlashAttention schedule re-expressed
with BlockSpecs instead of CUDA threadblocks (DESIGN.md
§Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, bk: int, tk: int, scale):
    q = q_ref[...].astype(jnp.float32) * scale  # (bq, dh)
    bq, dh = q.shape
    nkb = tk // bk

    def body(i, carry):
        m, l, acc = carry
        kblk = pl.load(k_ref, (pl.ds(i * bk, bk), slice(None))).astype(jnp.float32)
        vblk = pl.load(v_ref, (pl.ds(i * bk, bk), slice(None))).astype(jnp.float32)
        mblk = pl.load(mask_ref, (slice(None), pl.ds(i * bk, bk)))
        s = q @ kblk.T + mblk  # (bq, bk)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ vblk
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def _block(n: int, b: int) -> int:
    b = min(b, n)
    while n % b != 0:
        b -= 1
    return b


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Single-head attention with additive mask.

    q: (Tq, dh), k/v: (Tk, dh), mask: (Tq, Tk) additive. -> (Tq, dh).
    """
    tq, dh = q.shape
    tk = k.shape[0]
    assert k.shape == (tk, dh) and v.shape == (tk, dh)
    assert mask.shape == (tq, tk)
    bq = _block(tq, block_q)
    bk = _block(tk, block_k)
    scale = 1.0 / (dh ** 0.5)
    return pl.pallas_call(
        functools.partial(_attn_kernel, bk=bk, tk=tk, scale=scale),
        grid=(tq // bq,),
        in_specs=[
            pl.BlockSpec((bq, dh), lambda r: (r, 0)),
            pl.BlockSpec((tk, dh), lambda r: (0, 0)),
            pl.BlockSpec((tk, dh), lambda r: (0, 0)),
            pl.BlockSpec((bq, tk), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((bq, dh), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((tq, dh), q.dtype),
        interpret=True,
    )(q, k, v, mask)
