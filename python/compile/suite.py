"""Named artifact suites — the standard set `make artifacts` builds.

Experiment harnesses on the rust side reference configs by name
(`artifacts/<name>/`); this module is the single source of truth for
which configs exist. Keep it in sync with rust/src/experiments/.
"""

from __future__ import annotations

from .configs import Config, make_config

# Configs whose latency we benchmark get a `forward` artifact too.
_FORWARD = {
    "micro-baseline", "micro-altup", "micro-altup-k4", "micro-dense2x",
    "micro-dense4x", "micro-recycled", "micro-seqaltup", "micro-strideskip",
    "micro-avgpool", "micro-pallas-altup", "tiny-baseline", "tiny-altup",
    "tiny-dense2x", "mini-baseline", "mini-altup", "mini-recycled",
    "mini-dense2x", "small-baseline", "small-altup",
}


def wants_forward(name: str) -> bool:
    return name in _FORWARD


def _quality_suite() -> list[Config]:
    """Micro-scale configs for the quality experiments (Tables 1,2,6,7,8)."""
    cfgs = [
        # Table 7 / Table 1 / Fig 4 core variants at micro scale
        make_config("micro", "baseline", name="micro-baseline"),
        make_config("micro", "altup", k=2, name="micro-altup"),
        make_config("micro", "altup", k=4, name="micro-altup-k4"),
        make_config("micro", "sameup", k=2, name="micro-sameup"),
        make_config("micro", "sum", k=2, name="micro-sum"),
        make_config("micro", "recycled", k=2, name="micro-recycled"),
        # Table 4 dense scaling
        make_config("micro", "dense_wide", k=2, name="micro-dense2x"),
        make_config("micro", "dense_wide", k=4, name="micro-dense4x"),
        # Table 2 sequence-length reduction
        make_config("micro", "seq_altup", name="micro-seqaltup"),
        make_config("micro", "stride_skip", name="micro-strideskip"),
        make_config("micro", "avg_pool", name="micro-avgpool"),
        # Table 6 MoE synergy
        make_config("micro", "baseline", moe=True, name="micro-moe"),
        make_config("micro", "altup", k=2, moe=True, name="micro-altup-moe"),
        # L1 kernels exercised end-to-end (correctness artifact)
        make_config("micro", "altup", k=2, kernels="pallas",
                    name="micro-pallas-altup"),
    ]
    return cfgs


def _scale_suite() -> list[Config]:
    """Larger testbed scales for Fig 4's size axis and the e2e example."""
    return [
        make_config("tiny", "baseline", name="tiny-baseline"),
        make_config("tiny", "altup", k=2, name="tiny-altup"),
        make_config("tiny", "dense_wide", k=2, name="tiny-dense2x"),
        make_config("mini", "baseline", name="mini-baseline"),
        make_config("mini", "altup", k=2, name="mini-altup"),
        make_config("mini", "recycled", k=2, name="mini-recycled"),
        make_config("mini", "dense_wide", k=2, name="mini-dense2x"),
    ]


def _e2e_suite() -> list[Config]:
    """The paper's T5-small shape (~70M params) for the e2e example."""
    return [
        make_config("small", "baseline", name="small-baseline", dec_len=16,
                    batch_size=4),
        make_config("small", "altup", k=2, name="small-altup", dec_len=16,
                    batch_size=4),
    ]


def suite(name: str) -> list[Config]:
    if name == "quality":
        return _quality_suite()
    if name == "scale":
        return _scale_suite()
    if name == "e2e":
        return _e2e_suite()
    if name == "standard":
        return _quality_suite() + _scale_suite()
    if name == "all":
        return _quality_suite() + _scale_suite() + _e2e_suite()
    if name == "quickstart":
        return [
            make_config("micro", "baseline", name="micro-baseline"),
            make_config("micro", "altup", k=2, name="micro-altup"),
        ]
    raise ValueError(f"unknown suite: {name}")
