"""Model / variant / training configuration for the L2 JAX model.

A single ``Config`` drives every paper variant. The rust coordinator
consumes the same JSON (mirrored in ``rust/src/config``): ``aot.py``
embeds the full config dict in each artifact's ``meta.json`` so the two
sides can never drift.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

# Paper variants (Secs. 3-4, Tables 1-8).
VARIANTS = (
    "baseline",        # dense T5 at width d
    "dense_wide",      # dense T5 at width K*d  (Table 4 Dense2X/4X)
    "altup",           # Alg. 1, alternating block selection (default)
    "sameup",          # Alg. 1, same block selection       (Table 7)
    "sum",             # widened embedding summed into d     (Table 7)
    "recycled",        # Recycled-AltUp (Sec. 4.1)
    "seq_altup",       # Sequence-AltUp (Sec. 4.2, Alg. 2)
    "stride_skip",     # stride-and-skip baseline (Fig. 3 left)
    "avg_pool",        # average pooling baseline (Table 2)
)


@dataclasses.dataclass
class Config:
    """Everything needed to build + lower one model."""

    name: str = "micro-baseline"
    # -- architecture (T5 v1.1 style: pre-LN, gated GELU, RMSNorm) --
    d_model: int = 64
    d_ff: int = 128
    num_heads: int = 4
    d_head: int = 16
    enc_layers: int = 2
    dec_layers: int = 2
    vocab_size: int = 2048
    rel_pos_buckets: int = 32
    rel_pos_max_dist: int = 128
    # -- sequence geometry (static for AOT) --
    enc_len: int = 64
    dec_len: int = 32
    batch_size: int = 8
    # -- variant --
    variant: str = "baseline"
    k: int = 2                  # AltUp expansion factor K (or dense widening)
    seq_stride: int = 4         # Sequence-AltUp / stride-skip / avg-pool stride
    seq_first_layer: int = 1    # apply seq reduction to enc layers [first, L-1)
    # -- MoE partial experts (App. C) --
    moe: bool = False
    moe_experts: int = 16
    moe_hidden: int = 16
    # -- kernels --
    kernels: str = "jnp"        # "jnp" (fused reference) | "pallas" (L1 kernels)
    # -- training --
    dropout: float = 0.0
    label_smoothing: float = 0.0
    tie_embeddings: bool = False  # v1.1: input table shared enc/dec, head untied

    def __post_init__(self) -> None:
        self.validate()

    # - helpers -------------------------------------------------------
    def validate(self) -> None:
        assert self.variant in VARIANTS, self.variant
        assert self.num_heads * self.d_head > 0
        if self.variant in ("altup", "sameup", "recycled", "sum", "dense_wide"):
            assert self.k >= 2, "widened variants need K >= 2"
        if self.variant in ("seq_altup", "stride_skip", "avg_pool"):
            assert self.enc_len % self.seq_stride == 0
        assert self.kernels in ("jnp", "pallas")

    @property
    def repr_width(self) -> int:
        """Width of the token representation carried between layers."""
        if self.variant in ("altup", "sameup", "recycled"):
            return self.k * self.d_model
        if self.variant == "dense_wide":
            return self.k * self.d_model
        return self.d_model

    @property
    def layer_width(self) -> int:
        """Width of each transformer layer (d_model in the paper)."""
        if self.variant == "dense_wide":
            return self.k * self.d_model
        return self.d_model

    @property
    def embed_width(self) -> int:
        """Width of the embedding table rows."""
        if self.variant in ("altup", "sameup", "sum", "dense_wide"):
            return self.repr_width if self.variant != "sum" else self.k * self.d_model
        return self.d_model  # baseline, recycled, sequence variants

    @property
    def altup_blocks(self) -> int:
        return self.k if self.variant in ("altup", "sameup", "recycled") else 1

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict[str, Any]) -> "Config":
        return Config(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)


# Named size presets (scaled for the single-core CPU testbed; the
# paper-scale presets exist for analytic parameter counting only).
SIZES: dict[str, dict[str, int]] = {
    # testbed scales
    "micro": dict(d_model=64, d_ff=128, num_heads=4, d_head=16,
                  enc_layers=2, dec_layers=2, vocab_size=2048,
                  enc_len=64, dec_len=32, batch_size=8),
    "tiny": dict(d_model=128, d_ff=256, num_heads=4, d_head=32,
                 enc_layers=3, dec_layers=3, vocab_size=4096,
                 enc_len=64, dec_len=32, batch_size=8),
    "mini": dict(d_model=256, d_ff=512, num_heads=8, d_head=32,
                 enc_layers=4, dec_layers=4, vocab_size=8192,
                 enc_len=64, dec_len=32, batch_size=8),
    # the paper's "S" (T5 v1.1 small, 4+4 layers): e2e example scale
    "small": dict(d_model=512, d_ff=1024, num_heads=6, d_head=64,
                  enc_layers=4, dec_layers=4, vocab_size=32128,
                  enc_len=64, dec_len=32, batch_size=8),
    # paper-scale presets — analytic counting only (Tables 3-5)
    "base": dict(d_model=768, d_ff=2048, num_heads=12, d_head=64,
                 enc_layers=12, dec_layers=12, vocab_size=32128,
                 enc_len=512, dec_len=114, batch_size=256),
    "large": dict(d_model=1024, d_ff=2816, num_heads=16, d_head=64,
                  enc_layers=24, dec_layers=24, vocab_size=32128,
                  enc_len=512, dec_len=114, batch_size=256),
    "xl": dict(d_model=2048, d_ff=5120, num_heads=32, d_head=64,
               enc_layers=24, dec_layers=24, vocab_size=32128,
               enc_len=512, dec_len=114, batch_size=256),
}


def make_config(size: str, variant: str = "baseline", **overrides: Any) -> Config:
    base = dict(SIZES[size])
    base.update(variant=variant, name=f"{size}-{variant}")
    base.update(overrides)
    return Config(**base)
