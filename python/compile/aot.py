"""AOT pipeline: lower the L2 step functions to HLO *text* + meta.json.

HLO text (not ``.serialize()``) is the interchange format — the image's
xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction ids;
the text parser reassigns ids (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --suite standard --out ../artifacts
    python -m compile.aot --size micro --variant altup --k 2 --out ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import suite as S
from . import train as T
from .configs import Config, make_config


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_specs(cfg: Config):
    f32 = jnp.float32
    i32 = jnp.int32
    u32 = jnp.uint32
    b, te, td = cfg.batch_size, cfg.enc_len, cfg.dec_len
    pspecs = sorted(M.param_specs(cfg), key=lambda s: s.name)
    params = [jax.ShapeDtypeStruct(tuple(s.shape), f32) for s in pspecs]
    opt = [jax.ShapeDtypeStruct(tuple(s["shape"]), f32) for s in T.opt_state_specs(cfg)]
    scalars = [
        jax.ShapeDtypeStruct((), f32),  # step
        jax.ShapeDtypeStruct((), f32),  # lr
        jax.ShapeDtypeStruct((), u32),  # dropout seed
    ]
    batch = [
        jax.ShapeDtypeStruct((b, te), i32),  # enc tokens
        jax.ShapeDtypeStruct((b, td), i32),  # dec input
        jax.ShapeDtypeStruct((b, td), i32),  # dec targets
    ]
    return pspecs, params, opt, scalars, batch


def lower_config(cfg: Config, out_dir: str, *, with_decode: bool = True,
                 with_forward: bool = False) -> dict:
    """Lower train/eval(/decode/forward) for one config; write artifacts."""
    os.makedirs(out_dir, exist_ok=True)
    pspecs, params, opt, scalars, batch = _shape_specs(cfg)

    t0 = time.time()
    artifacts: dict[str, str] = {}

    train_fn = T.make_train_step(cfg)
    lowered = jax.jit(train_fn, keep_unused=True).lower(*params, *opt, *scalars, *batch)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
        f.write(text)
    artifacts["train_step"] = "train_step.hlo.txt"

    eval_fn = T.make_eval_step(cfg)
    lowered = jax.jit(eval_fn, keep_unused=True).lower(*params, *batch)
    with open(os.path.join(out_dir, "eval_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    artifacts["eval_step"] = "eval_step.hlo.txt"

    if with_decode:
        dec_fn = T.make_decode_step(cfg)
        lowered = jax.jit(dec_fn, keep_unused=True).lower(*params, batch[0])
        with open(os.path.join(out_dir, "decode_step.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        artifacts["decode_step"] = "decode_step.hlo.txt"

    if with_forward:
        fwd_fn = T.make_forward(cfg)
        lowered = jax.jit(fwd_fn, keep_unused=True).lower(*params, batch[0], batch[1])
        with open(os.path.join(out_dir, "forward.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        artifacts["forward"] = "forward.hlo.txt"

    counts = M.count_params(cfg)
    meta = {
        "name": cfg.name,
        "config": cfg.to_dict(),
        "params": [s.to_dict() for s in pspecs],
        "opt_state": T.opt_state_specs(cfg),
        "scalars": [
            {"name": "step", "dtype": "f32"},
            {"name": "lr", "dtype": "f32"},
            {"name": "seed", "dtype": "u32"},
        ],
        "batch_inputs": [
            {"name": "enc_tokens", "shape": [cfg.batch_size, cfg.enc_len], "dtype": "i32"},
            {"name": "dec_input", "shape": [cfg.batch_size, cfg.dec_len], "dtype": "i32"},
            {"name": "dec_targets", "shape": [cfg.batch_size, cfg.dec_len], "dtype": "i32"},
        ],
        "train_outputs": ["params...", "opt_state...", "loss", "correct", "ntok"],
        "eval_outputs": ["loss_sum", "correct", "ntok"],
        "artifacts": artifacts,
        "param_count": counts,
        "flops_per_token": M.flops_per_token(cfg),
        "lowering_seconds": round(time.time() - t0, 2),
        "jax_version": jax.__version__,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--suite", default=None, help="named suite from suite.py")
    ap.add_argument("--size", default=None)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--kernels", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--moe", action="store_true")
    ap.add_argument("--no-decode", action="store_true")
    ap.add_argument("--forward", action="store_true")
    args = ap.parse_args()

    configs: list[Config]
    if args.suite:
        configs = S.suite(args.suite)
    else:
        assert args.size, "--size or --suite required"
        configs = [
            make_config(
                args.size, args.variant, k=args.k, kernels=args.kernels, moe=args.moe
            )
        ]

    for cfg in configs:
        out_dir = os.path.join(args.out, cfg.name)
        marker = os.path.join(out_dir, "meta.json")
        cfg_hash = hashlib.sha256(cfg.to_json().encode()).hexdigest()[:16]
        if os.path.exists(marker):
            try:
                with open(marker) as f:
                    old = json.load(f)
                old_hash = hashlib.sha256(
                    Config.from_dict(old["config"]).to_json().encode()
                ).hexdigest()[:16]
                if old_hash == cfg_hash and all(
                    os.path.exists(os.path.join(out_dir, p))
                    for p in old.get("artifacts", {}).values()
                ):
                    print(f"[aot] {cfg.name}: up to date, skipping")
                    continue
            except Exception:
                pass
        print(f"[aot] lowering {cfg.name} ...", flush=True)
        meta = lower_config(
            cfg, out_dir,
            with_decode=not args.no_decode,
            with_forward=args.forward or S.wants_forward(cfg.name),
        )
        print(
            f"[aot] {cfg.name}: {meta['param_count']['total']:,} params, "
            f"{meta['lowering_seconds']}s"
        )


if __name__ == "__main__":
    sys.exit(main())
