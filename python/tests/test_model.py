"""L2 model correctness: shapes, variant semantics, analytic counts."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import train as T
from compile.configs import Config, make_config, SIZES, VARIANTS

RNG = np.random.default_rng(0)


def toks(b, t, v, rng=RNG):
    return jnp.asarray(rng.integers(1, v, size=(b, t)), jnp.int32)


def small_cfg(variant, **kw):
    return make_config("micro", variant, enc_len=16, dec_len=8, batch_size=2, **kw)


ALL = [
    ("baseline", {}),
    ("altup", {"k": 2}),
    ("altup", {"k": 4}),
    ("sameup", {"k": 2}),
    ("sum", {"k": 2}),
    ("recycled", {"k": 2}),
    ("dense_wide", {"k": 2}),
    ("seq_altup", {}),
    ("stride_skip", {}),
    ("avg_pool", {}),
    ("baseline", {"moe": True}),
    ("altup", {"k": 2, "moe": True}),
]


@pytest.mark.parametrize("variant,kw", ALL)
def test_forward_shapes_and_finite(variant, kw):
    cfg = small_cfg(variant, **kw)
    params = M.init_params(cfg, 0)
    logits = M.forward(params, toks(2, 16, cfg.vocab_size), toks(2, 8, cfg.vocab_size), cfg)
    assert logits.shape == (2, 8, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("variant,kw", ALL)
def test_param_specs_match_init(variant, kw):
    cfg = small_cfg(variant, **kw)
    params = M.init_params(cfg, 0)
    specs = {s.name: tuple(s.shape) for s in M.param_specs(cfg)}
    assert set(specs) == set(params)
    for name, shape in specs.items():
        assert params[name].shape == shape, name


def test_padding_invariance():
    """Extending the encoder input with pad tokens must not change logits."""
    cfg = small_cfg("altup")
    params = M.init_params(cfg, 0)
    enc = np.asarray(toks(2, 16, cfg.vocab_size))
    enc_padded = enc.copy()
    enc_padded[:, 10:] = 0
    dec = toks(2, 8, cfg.vocab_size)
    l1 = M.forward(params, jnp.asarray(enc_padded), dec, cfg)
    # Same content in a physically identical buffer -> identical
    l2 = M.forward(params, jnp.asarray(enc_padded.copy()), dec, cfg)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=0, atol=0)


def test_decoder_causality():
    """Changing a later decoder token must not affect earlier logits."""
    cfg = small_cfg("baseline")
    params = M.init_params(cfg, 0)
    enc = toks(2, 16, cfg.vocab_size)
    dec = np.asarray(toks(2, 8, cfg.vocab_size))
    l1 = M.forward(params, enc, jnp.asarray(dec), cfg)
    dec2 = dec.copy()
    dec2[:, 5:] = (dec2[:, 5:] % (cfg.vocab_size - 1)) + 1
    l2 = M.forward(params, enc, jnp.asarray(dec2), cfg)
    np.testing.assert_allclose(
        np.asarray(l1)[:, :5], np.asarray(l2)[:, :5], rtol=1e-5, atol=1e-5
    )
    assert np.abs(np.asarray(l1)[:, 5:] - np.asarray(l2)[:, 5:]).max() > 1e-4


def test_altup_causality():
    cfg = small_cfg("altup")
    params = M.init_params(cfg, 0)
    enc = toks(2, 16, cfg.vocab_size)
    dec = np.asarray(toks(2, 8, cfg.vocab_size))
    l1 = M.forward(params, enc, jnp.asarray(dec), cfg)
    dec2 = dec.copy()
    dec2[:, -1] = (dec2[:, -1] % (cfg.vocab_size - 1)) + 1
    l2 = M.forward(params, enc, jnp.asarray(dec2), cfg)
    np.testing.assert_allclose(
        np.asarray(l1)[:, :-1], np.asarray(l2)[:, :-1], rtol=1e-5, atol=1e-5
    )


def test_recycled_embeds_replicated():
    cfg = small_cfg("recycled")
    params = M.init_params(cfg, 0)
    e = M.embed(params, toks(2, 16, cfg.vocab_size), cfg)
    assert e.shape == (cfg.k, 2, 16, cfg.d_model)
    np.testing.assert_allclose(np.asarray(e[0]), np.asarray(e[1]), rtol=0, atol=0)


def test_recycled_adds_virtually_no_params():
    base = M.count_params(small_cfg("baseline"))
    rec = M.count_params(small_cfg("recycled"))
    # only the K^2+K scalars per layer
    cfg = small_cfg("recycled")
    layers = cfg.enc_layers + cfg.dec_layers
    assert rec["total"] - base["total"] == layers * (cfg.k**2 + cfg.k)


def test_altup_param_overhead_matches_paper_formula():
    """AltUp adds (K-1)*|V|*d embedding params + K^2+K scalars/layer
    + the widened output head."""
    cfg = small_cfg("altup")
    base = small_cfg("baseline")
    pa = M.count_params(cfg)
    pb = M.count_params(base)
    layers = cfg.enc_layers + cfg.dec_layers
    emb_extra = (cfg.k - 1) * cfg.vocab_size * cfg.d_model  # input table
    head_extra = (cfg.k - 1) * cfg.d_model * cfg.vocab_size  # output head
    assert pa["embedding"] - pb["embedding"] == emb_extra + head_extra
    assert pa["non_embedding"] - pb["non_embedding"] == layers * (cfg.k**2 + cfg.k)


def test_sum_variant_only_widens_embedding():
    pa = M.count_params(small_cfg("sum"))
    pb = M.count_params(small_cfg("baseline"))
    cfg = small_cfg("sum")
    assert pa["non_embedding"] == pb["non_embedding"]
    assert pa["embedding"] - pb["embedding"] == (cfg.k - 1) * cfg.vocab_size * cfg.d_model


def test_altup_init_is_identity_schedule():
    """At init (p=I, g=1) the computed block equals L(x_j*) exactly."""
    cfg = small_cfg("altup")
    params = M.init_params(cfg, 0)
    k, b, t, d = cfg.k, 2, 4, cfg.d_model
    x = jnp.asarray(RNG.normal(size=(k, b, t, d)), jnp.float32)
    layer_out = jnp.asarray(RNG.normal(size=(b, t, d)), jnp.float32)
    got = M.altup_step(params, "enc/l0", x, lambda blk: layer_out, 1, cfg)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(layer_out), rtol=1e-6, atol=1e-6)
    # non-computed blocks get x_i + (L(x_1) - x_1)
    want0 = x[0] + (layer_out - x[1])
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want0), rtol=1e-5, atol=1e-5)


def test_block_selection_schedules():
    cfg_alt = small_cfg("altup", k=2)
    assert [M.select_block(i, cfg_alt) for i in range(4)] == [0, 1, 0, 1]
    cfg_same = small_cfg("sameup", k=2)
    assert [M.select_block(i, cfg_same) for i in range(4)] == [0, 0, 0, 0]
    cfg4 = small_cfg("altup", k=4)
    assert [M.select_block(i, cfg4) for i in range(6)] == [0, 1, 2, 3, 0, 1]


def test_loss_ignores_padding():
    cfg = small_cfg("baseline")
    params = M.init_params(cfg, 0)
    enc = toks(2, 16, cfg.vocab_size)
    dec = np.asarray(toks(2, 8, cfg.vocab_size))
    logits = M.forward(params, enc, jnp.asarray(dec), cfg)
    tgt = dec.copy()
    tgt[:, 6:] = 0
    l1, c1, n1 = M.loss_and_metrics(logits, jnp.asarray(tgt))
    assert float(n1) == 2 * 6
    # scaling logits at padded positions must not change the loss
    logits2 = np.asarray(logits).copy()
    logits2[:, 6:] *= 3.0
    l2, _, _ = M.loss_and_metrics(jnp.asarray(logits2), jnp.asarray(tgt))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_greedy_decode_shape_and_determinism():
    cfg = small_cfg("altup")
    params = M.init_params(cfg, 0)
    enc = toks(2, 16, cfg.vocab_size)
    out1 = M.greedy_decode(params, enc, cfg)
    out2 = M.greedy_decode(params, enc, cfg)
    assert out1.shape == (2, cfg.dec_len)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_avg_pool_reduces_memory_length():
    cfg = small_cfg("avg_pool")
    params = M.init_params(cfg, 0)
    mem, valid = M.encode(params, toks(2, 16, cfg.vocab_size), cfg, jnp.uint32(0))
    assert mem.shape == (2, 16 // cfg.seq_stride, cfg.d_model)
    assert valid.shape == (2, 16 // cfg.seq_stride)


def test_seq_variants_preserve_length():
    for v in ("seq_altup", "stride_skip"):
        cfg = small_cfg(v)
        params = M.init_params(cfg, 0)
        mem, valid = M.encode(params, toks(2, 16, cfg.vocab_size), cfg, jnp.uint32(0))
        assert mem.shape == (2, 16, cfg.d_model), v


def test_stride_skip_identity_on_skipped_tokens_single_layer():
    """In the reduced window, non-anchor tokens pass through unchanged
    (Fig. 3 left) — check at the level of one seq_reduced_layer call."""
    cfg = small_cfg("stride_skip")
    params = M.init_params(cfg, 0)
    b, t, d, s = 2, 16, cfg.d_model, cfg.seq_stride
    x = jnp.asarray(RNG.normal(size=(b, t, d)), jnp.float32)
    valid = jnp.ones((b, t // s), bool)
    mask_sub = M._attn_mask(valid, valid, causal=False)
    y = M.seq_reduced_layer(params, "enc/l1", x, mask_sub, None, cfg, jnp.uint32(0), 0)
    keep = np.arange(t) % s != 0
    np.testing.assert_allclose(
        np.asarray(y)[:, keep], np.asarray(x)[:, keep], rtol=0, atol=0
    )
    assert np.abs(np.asarray(y)[:, ~keep] - np.asarray(x)[:, ~keep]).max() > 1e-4


def test_dropout_zero_is_deterministic():
    cfg = small_cfg("altup", dropout=0.0)
    params = M.init_params(cfg, 0)
    enc, dec = toks(2, 16, cfg.vocab_size), toks(2, 8, cfg.vocab_size)
    l1 = M.forward(params, enc, dec, cfg, seed=jnp.uint32(1))
    l2 = M.forward(params, enc, dec, cfg, seed=jnp.uint32(2))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=0, atol=0)


def test_dropout_seed_changes_output():
    cfg = small_cfg("baseline", dropout=0.5)
    params = M.init_params(cfg, 0)
    enc, dec = toks(2, 16, cfg.vocab_size), toks(2, 8, cfg.vocab_size)
    l1 = M.forward(params, enc, dec, cfg, seed=jnp.uint32(1))
    l2 = M.forward(params, enc, dec, cfg, seed=jnp.uint32(2))
    assert np.abs(np.asarray(l1) - np.asarray(l2)).max() > 1e-3


def test_moe_adds_capacity_params():
    pa = M.count_params(small_cfg("baseline", moe=True))
    pb = M.count_params(small_cfg("baseline"))
    cfg = small_cfg("baseline", moe=True)
    layers = cfg.enc_layers + cfg.dec_layers
    per_layer = (
        cfg.d_model * cfg.moe_experts
        + cfg.moe_experts * cfg.d_model * cfg.moe_hidden * 2
    )
    assert pa["total"] - pb["total"] == layers * per_layer


def test_flops_ordering():
    """Dense widening must cost ~K^2 more FLOPs; AltUp ~= baseline."""
    f_base = M.flops_per_token(small_cfg("baseline"))
    f_alt = M.flops_per_token(small_cfg("altup"))
    f_d2 = M.flops_per_token(small_cfg("dense_wide", k=2))
    assert f_alt < 1.05 * f_base
    assert f_d2 > 2.5 * f_base
