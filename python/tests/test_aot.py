"""AOT pipeline: lowering produces parseable HLO text with a stable
signature, and meta.json round-trips the config."""

from __future__ import annotations

import json
import os

import pytest

from compile import aot
from compile import model as M
from compile import suite as S
from compile import train as T
from compile.configs import Config, make_config


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = make_config(
        "micro", "altup", k=2, enc_len=16, dec_len=8, batch_size=2,
        name="test-altup",
    )
    meta = aot.lower_config(cfg, str(out / cfg.name), with_forward=True)
    return cfg, meta, out / cfg.name


def test_artifacts_exist(lowered):
    cfg, meta, out = lowered
    for rel in meta["artifacts"].values():
        p = out / rel
        assert p.exists() and p.stat().st_size > 1000, rel


def test_hlo_is_text(lowered):
    cfg, meta, out = lowered
    text = (out / "train_step.hlo.txt").read_text()
    assert text.startswith("HloModule"), text[:40]
    assert "ENTRY" in text


def test_meta_param_order_sorted(lowered):
    cfg, meta, out = lowered
    names = [p["name"] for p in meta["params"]]
    assert names == sorted(names)
    assert names == T.param_order(cfg)


def test_meta_config_roundtrip(lowered):
    cfg, meta, out = lowered
    cfg2 = Config.from_dict(meta["config"])
    assert cfg2.to_dict() == cfg.to_dict()


def test_signature_counts(lowered):
    cfg, meta, out = lowered
    n_inputs_train = (
        len(meta["params"]) + len(meta["opt_state"]) + len(meta["scalars"])
        + len(meta["batch_inputs"])
    )
    text = (out / "train_step.hlo.txt").read_text()
    # count parameter instructions in the entry computation
    n_params_in_hlo = text.count(" = f32[") + text.count(" = s32[") + text.count(" = u32[")
    assert text.count("parameter(") >= n_inputs_train
    assert n_inputs_train == len(meta["params"]) + len(meta["opt_state"]) + 6


def test_param_count_consistency(lowered):
    cfg, meta, out = lowered
    total = 0
    for p in meta["params"]:
        n = 1
        for s in p["shape"]:
            n *= s
        total += n
    assert total == meta["param_count"]["total"]
    assert meta["param_count"]["total"] == M.count_params(cfg)["total"]


def test_suites_are_wellformed():
    for name in ("quality", "scale", "e2e", "standard", "quickstart"):
        cfgs = S.suite(name)
        assert cfgs
        names = [c.name for c in cfgs]
        assert len(set(names)) == len(names), f"duplicate names in {name}"
        for c in cfgs:
            c.validate()


def test_skip_up_to_date(lowered, capsys):
    cfg, meta, out = lowered
    # second lowering of the same config should be skipped by the
    # freshness check in main(); emulate it directly
    import hashlib
    with open(out / "meta.json") as f:
        old = json.load(f)
    h1 = hashlib.sha256(Config.from_dict(old["config"]).to_json().encode()).hexdigest()
    h2 = hashlib.sha256(cfg.to_json().encode()).hexdigest()
    assert h1 == h2
