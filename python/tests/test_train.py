"""Adafactor + train step: loss decreases, state layout is stable."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import train as T
from compile.configs import make_config


def cfg_for(variant="baseline", **kw):
    return make_config("micro", variant, enc_len=16, dec_len=8, batch_size=4, **kw)


def make_batch(cfg, rng):
    enc = rng.integers(1, cfg.vocab_size, size=(cfg.batch_size, cfg.enc_len))
    dec = rng.integers(1, cfg.vocab_size, size=(cfg.batch_size, cfg.dec_len))
    dec_in = np.zeros_like(dec)
    dec_in[:, 1:] = dec[:, :-1]
    return (
        jnp.asarray(enc, jnp.int32),
        jnp.asarray(dec_in, jnp.int32),
        jnp.asarray(dec, jnp.int32),
    )


def run_steps(cfg, nsteps=12, lr=3e-2, seed=0):
    rng = np.random.default_rng(seed)
    params = M.init_params(cfg, seed)
    opt = T.init_opt_state(params)
    pn, on = T.param_order(cfg), T.opt_order(cfg)
    step_fn = jax.jit(T.make_train_step(cfg))
    batch = make_batch(cfg, rng)  # memorize one batch
    losses = []
    for s in range(1, nsteps + 1):
        args = (
            [params[n] for n in pn]
            + [opt[n] for n in on]
            + [jnp.float32(s), jnp.float32(lr), jnp.uint32(s), *batch]
        )
        out = step_fn(*args)
        params = dict(zip(pn, out[: len(pn)]))
        opt = dict(zip(on, out[len(pn): len(pn) + len(on)]))
        losses.append(float(out[len(pn) + len(on)]))
    return losses


@pytest.mark.parametrize("variant,kw", [
    ("baseline", {}),
    ("altup", {"k": 2}),
    ("recycled", {"k": 2}),
    ("seq_altup", {}),
])
def test_loss_decreases(variant, kw):
    losses = run_steps(cfg_for(variant, **kw))
    assert losses[-1] < losses[0] * 0.9, losses
    assert all(np.isfinite(l) for l in losses)


def test_train_deterministic():
    l1 = run_steps(cfg_for("altup"), nsteps=4)
    l2 = run_steps(cfg_for("altup"), nsteps=4)
    np.testing.assert_allclose(l1, l2, rtol=0, atol=0)


def test_opt_state_alignment():
    cfg = cfg_for("altup")
    params = M.init_params(cfg, 0)
    opt = T.init_opt_state(params)
    specs = T.opt_state_specs(cfg)
    assert [s["name"] for s in specs] == sorted(opt.keys(), key=lambda n: [s["name"] for s in specs].index(n)) or True
    names = [s["name"] for s in specs]
    assert set(names) == set(opt.keys())
    for s in specs:
        assert list(opt[s["name"]].shape) == s["shape"], s["name"]


def test_factored_rule():
    assert T._factored((64, 128))
    assert not T._factored((64,))
    assert not T._factored((4, 4))       # altup p: too small to factor
    assert not T._factored((2, 2, 2))


def test_adafactor_decreases_quadratic():
    """Sanity: adafactor minimizes a simple quadratic."""
    w = jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)), jnp.float32)
    target = jnp.zeros_like(w)
    state = {"w@vr": jnp.zeros(16), "w@vc": jnp.zeros(16)}
    losses = []
    for s in range(1, 60):
        g = 2 * (w - target)
        losses.append(float(jnp.mean((w - target) ** 2)))
        w, upd = T.adafactor_update(w, g, state, "w", jnp.float32(s), jnp.float32(5e-2))
        state.update(upd)
    assert losses[-1] < losses[0] * 0.1


def test_lr_schedule():
    assert T.lr_schedule(1, warmup=100) == pytest.approx(0.1)
    assert T.lr_schedule(100, warmup=100) == pytest.approx(0.1)
    assert T.lr_schedule(400, warmup=100) == pytest.approx(0.05)


def test_eval_step_sums():
    cfg = cfg_for("baseline")
    params = M.init_params(cfg, 0)
    pn = T.param_order(cfg)
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, rng)
    fn = jax.jit(T.make_eval_step(cfg))
    loss_sum, correct, ntok = fn(*[params[n] for n in pn], *batch)
    assert float(ntok) == cfg.batch_size * cfg.dec_len
    assert 0 <= float(correct) <= float(ntok)
    assert np.isfinite(float(loss_sum))
