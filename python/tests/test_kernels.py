"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/block sizes; assert_allclose against
ref.py. This is the CORE correctness signal for the kernel layer.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import altup as kaltup
from compile.kernels import attention as kattn
from compile.kernels import ffn as kffn
from compile.kernels import grads as kgrad
from compile.kernels import ref as kref
from compile.kernels import seq_altup as kseq

SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


dtypes = st.sampled_from([jnp.float32, jnp.bfloat16])


def _tol(dtype):
    # bf16: the kernel accumulates the K-term mixture in bf16 (as a TPU
    # VPU would), while the jnp oracle's einsum accumulates in f32 — the
    # bound must cover K bf16 roundings (~0.8% each) of O(K)-magnitude
    # sums, so use a generous 8e-2.
    return dict(rtol=8e-2, atol=8e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    k=st.sampled_from([2, 3, 4]),
    t=st.integers(1, 9).map(lambda x: x * 16),
    d=st.sampled_from([8, 32, 64]),
    bt=st.sampled_from([16, 64, 256]),
    dtype=dtypes,
    seed=st.integers(0, 2**16),
)
def test_altup_predict(k, t, d, bt, dtype, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (k, t, d), dtype)
    p = _arr(rng, (k, k), dtype)
    got = kaltup.altup_predict(x, p, block_rows=bt)
    want = kref.altup_predict_ref(x, p)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@settings(**SETTINGS)
@given(
    k=st.sampled_from([2, 4]),
    t=st.integers(1, 6).map(lambda x: x * 16),
    d=st.sampled_from([8, 64]),
    jstar=st.integers(0, 3),
    dtype=dtypes,
    seed=st.integers(0, 2**16),
)
def test_altup_correct(k, t, d, jstar, dtype, seed):
    jstar = jstar % k
    rng = np.random.default_rng(seed)
    xhat = _arr(rng, (k, t, d), dtype)
    xtilde = _arr(rng, (t, d), dtype)
    g = _arr(rng, (k,), dtype)
    got = kaltup.altup_correct(xhat, xtilde, g, jstar, block_rows=32)
    want = kref.altup_correct_ref(xhat, xtilde, g, jstar)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@settings(**SETTINGS)
@given(
    k=st.sampled_from([2, 4]),
    t=st.integers(1, 6).map(lambda x: x * 16),
    d=st.sampled_from([16, 64]),
    jstar=st.integers(0, 3),
    seed=st.integers(0, 2**16),
)
def test_altup_fused_predict_correct(k, t, d, jstar, seed):
    jstar = jstar % k
    rng = np.random.default_rng(seed)
    x = _arr(rng, (k, t, d))
    xtilde = _arr(rng, (t, d))
    p = _arr(rng, (k, k))
    g = _arr(rng, (k,))
    got = kaltup.altup_predict_correct(x, xtilde, p, g, jstar, block_rows=48)
    xhat = kref.altup_predict_ref(x, p)
    want = kref.altup_correct_ref(xhat, xtilde, g, jstar)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    k=st.sampled_from([2, 3, 4]),
    t=st.integers(1, 5).map(lambda x: x * 16),
    d=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**16),
)
def test_recycled_downproject(k, t, d, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (k, t, d))
    got = kaltup.recycled_downproject(x, block_rows=32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(kref.recycled_downproject_ref(x)), rtol=1e-5, atol=1e-5
    )


@settings(**SETTINGS)
@given(
    t=st.integers(1, 4).map(lambda x: x * 32),
    d=st.sampled_from([16, 48]),
    f=st.sampled_from([64, 160]),
    bt=st.sampled_from([16, 64]),
    bf=st.sampled_from([32, 512]),
    seed=st.integers(0, 2**16),
)
def test_gated_ffn(t, d, f, bt, bf, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (t, d))
    wi0 = _arr(rng, (d, f), scale=0.1)
    wi1 = _arr(rng, (d, f), scale=0.1)
    wo = _arr(rng, (f, d), scale=0.1)
    got = kffn.gated_ffn(x, wi0, wi1, wo, block_rows=bt, block_hidden=bf)
    want = kref.gated_ffn_ref(x, wi0, wi1, wo)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


@settings(**SETTINGS)
@given(
    tq=st.sampled_from([16, 48, 64]),
    tk=st.sampled_from([16, 64, 96]),
    dh=st.sampled_from([8, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_flash_attention(tq, tk, dh, causal, seed):
    rng = np.random.default_rng(seed)
    q = _arr(rng, (tq, dh))
    k = _arr(rng, (tk, dh))
    v = _arr(rng, (tk, dh))
    if causal and tq == tk:
        mask = np.where(np.tril(np.ones((tq, tk))) > 0, 0.0, -1e9).astype(np.float32)
    else:
        mask = np.where(rng.random((tq, tk)) < 0.15, -1e9, 0.0).astype(np.float32)
        mask[:, 0] = 0.0  # at least one attendable key per row
    mask = jnp.asarray(mask)
    got = kattn.flash_attention(q, k, v, mask, block_q=16, block_k=16)
    want = kref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


@settings(**SETTINGS)
@given(
    t=st.integers(1, 6).map(lambda x: x * 16),
    d=st.sampled_from([8, 32]),
    stride=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_seq_altup(t, d, stride, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (t, d))
    a1 = jnp.float32(rng.normal())
    a2 = jnp.float32(rng.normal())
    b = jnp.float32(rng.normal())
    yhat = kseq.seq_altup_predict(x, a1, a2, stride, block_rows=32)
    np.testing.assert_allclose(
        np.asarray(yhat),
        np.asarray(kref.seq_altup_predict_ref(x, a1, a2, stride)),
        rtol=1e-5,
        atol=1e-5,
    )
    yt = _arr(rng, (t // stride, d))
    got = kseq.seq_altup_correct(yhat, yt, b, stride, block_rows=32)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(kref.seq_altup_correct_ref(yhat, yt, b, stride)),
        rtol=1e-5,
        atol=1e-5,
    )


# ---------------------------------------------------------------------
# custom_vjp wrappers: gradients must match the differentiated oracle
# ---------------------------------------------------------------------

def test_grad_altup_predict_correct_matches_ref():
    rng = np.random.default_rng(0)
    k, t, d, jstar = 4, 32, 16, 2
    x = _arr(rng, (k, t, d))
    xt = _arr(rng, (t, d))
    p = _arr(rng, (k, k))
    g = _arr(rng, (k,))

    def f_pal(x, xt, p, g):
        return jnp.sum(jnp.sin(kgrad.altup_predict_correct(x, xt, p, g, jstar)))

    def f_ref(x, xt, p, g):
        xhat = kref.altup_predict_ref(x, p)
        return jnp.sum(jnp.sin(kref.altup_correct_ref(xhat, xt, g, jstar)))

    gp = jax.grad(f_pal, argnums=(0, 1, 2, 3))(x, xt, p, g)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, xt, p, g)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_grad_ffn_matches_ref():
    rng = np.random.default_rng(1)
    t, d, f = 32, 16, 64
    x, wi0, wi1, wo = (
        _arr(rng, (t, d)),
        _arr(rng, (d, f), scale=0.1),
        _arr(rng, (d, f), scale=0.1),
        _arr(rng, (f, d), scale=0.1),
    )
    gp = jax.grad(lambda *a: jnp.sum(jnp.tanh(kgrad.gated_ffn(*a))), argnums=(0, 1, 2, 3))(
        x, wi0, wi1, wo
    )
    gr = jax.grad(
        lambda *a: jnp.sum(jnp.tanh(kref.gated_ffn_ref(*a))), argnums=(0, 1, 2, 3)
    )(x, wi0, wi1, wo)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_grad_attention_matches_ref():
    rng = np.random.default_rng(2)
    tq, tk, dh = 16, 32, 8
    q, k, v = _arr(rng, (tq, dh)), _arr(rng, (tk, dh)), _arr(rng, (tk, dh))
    mask = jnp.zeros((tq, tk), jnp.float32)
    gp = jax.grad(lambda *a: jnp.sum(jnp.tanh(kgrad.flash_attention(*a, mask))), argnums=(0, 1, 2))(
        q, k, v
    )
    gr = jax.grad(
        lambda *a: jnp.sum(jnp.tanh(kref.attention_ref(*a, mask))), argnums=(0, 1, 2)
    )(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------
# Kernel edge cases
# ---------------------------------------------------------------------

def test_predict_identity_mixing_is_identity():
    """p = I must reproduce the input exactly (paper init)."""
    rng = np.random.default_rng(3)
    x = _arr(rng, (4, 32, 16))
    got = kaltup.altup_predict(x, jnp.eye(4))
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=0, atol=0)


def test_correct_zero_gain_keeps_prediction():
    rng = np.random.default_rng(4)
    xhat = _arr(rng, (2, 16, 8))
    xt = _arr(rng, (16, 8))
    got = kaltup.altup_correct(xhat, xt, jnp.zeros(2), 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(xhat), rtol=0, atol=0)


def test_correct_unit_gain_computed_block_gets_layer_output():
    """With g[j*]=1 the computed block becomes exactly L(x_j*)."""
    rng = np.random.default_rng(5)
    k, t, d, jstar = 3, 16, 8, 1
    xhat = _arr(rng, (k, t, d))
    xt = _arr(rng, (t, d))
    g = jnp.ones(k)
    got = kaltup.altup_correct(xhat, xt, g, jstar)
    np.testing.assert_allclose(np.asarray(got[jstar]), np.asarray(xt), rtol=1e-6, atol=1e-6)


def test_seq_altup_stride_1_predict_is_affine():
    """stride=1: every token is its own anchor -> yhat = (a1+a2) x."""
    rng = np.random.default_rng(6)
    x = _arr(rng, (16, 8))
    got = kseq.seq_altup_predict(x, jnp.float32(0.3), jnp.float32(0.5), 1)
    np.testing.assert_allclose(np.asarray(got), 0.8 * np.asarray(x), rtol=1e-5, atol=1e-6)


def test_attention_fully_masked_rows_are_finite():
    rng = np.random.default_rng(7)
    q, k, v = _arr(rng, (8, 8)), _arr(rng, (16, 8)), _arr(rng, (16, 8))
    mask = jnp.full((8, 16), -1e9, jnp.float32)
    got = kattn.flash_attention(q, k, v, mask, block_q=8, block_k=8)
    assert np.isfinite(np.asarray(got)).all()
