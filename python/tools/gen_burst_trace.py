"""Deterministic generator for the checked-in §L10 load traces
(`rust/benches/traces/*.trace`).

Trace format (one request per line, '#' lines are comments):

    #altup-trace v1 seed=0x51C0DE
    # arrival_us tenant prompt_len
    0 0 12
    410 2 57
    ...

`arrival_us` is the request's arrival offset from trace start in
microseconds (non-decreasing), `tenant` indexes the serving config's
tenant spec (0 = free, 1 = silver, 2 = gold for the default spec), and
`prompt_len` is the prompt length in tokens. Prompt *tokens* are not
stored: both loaders (the Rust bench and the Python twin) materialize
them from one shared SplitMix64 stream seeded by the header `seed` —
`prompt_len` draws of `rng.range(1, vocab)` per line, in file order —
so the hash-sampled generation lengths match bit-for-bit across the
two harnesses and the file stays small.

The arrival process is deliberately hostile (§L10 chaos harness):

- **bursty**: on/off square wave — `--burst-ms` of Poisson arrivals at
  `--peak-qps`, then `--idle-ms` of silence — so queue depth whipsaws
  instead of settling into a steady state;
- **heavy-tailed lengths**: 70% short [4, 32), 25% medium [32, 96),
  5% long [96, 128) — the long tail holds slots hostage;
- **tenant-skewed**: 55% free / 30% silver / 15% gold, so the lowest
  class dominates offered load and is the natural shed target.

Everything derives from `--seed` (SplitMix64 mirror of
`rust/src/util/rng.rs`); regenerating with the same flags reproduces
the file byte-for-byte. The checked-in `burst_mix.trace` was produced
with the defaults below; its peak rate is tuned to >= 2x the measured
cont-x2 capacity of the twin on the reference container, so replaying
it *is* an overload test, not a throughput test.

Usage: python3 python/tools/gen_burst_trace.py \
           [--out rust/benches/traces/burst_mix.trace] [--requests 1800]
           [--peak-qps 4000] [--burst-ms 250] [--idle-ms 150]
           [--seed 0x51C0DE]
"""

import argparse
import math

MASK = (1 << 64) - 1


class Rng:
    """SplitMix64, matching rust/src/util/rng.rs bit-for-bit."""

    def __init__(self, seed):
        self.state = (seed + 0x9E3779B97F4A7C15) & MASK

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def range(self, lo, hi):
        return lo + ((self.next_u64() * (hi - lo)) >> 64)


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--out", default="rust/benches/traces/burst_mix.trace")
    ap.add_argument("--requests", type=int, default=1800)
    ap.add_argument("--peak-qps", type=float, default=4000.0)
    ap.add_argument("--burst-ms", type=float, default=250.0)
    ap.add_argument("--idle-ms", type=float, default=150.0)
    ap.add_argument("--seed", type=lambda s: int(s, 0), default=0x51C0DE)
    args = ap.parse_args()

    rng = Rng(args.seed)
    lines = []
    t_us = 0.0
    burst_us = args.burst_ms * 1e3
    idle_us = args.idle_ms * 1e3
    phase_start = 0.0
    counts = [0, 0, 0]
    for _ in range(args.requests):
        # Poisson arrivals at peak rate during the ON phase; crossing
        # the phase boundary jumps the clock over the OFF gap.
        t_us += -math.log(1.0 - rng.next_f64()) / args.peak_qps * 1e6
        while t_us - phase_start >= burst_us:
            phase_start += burst_us + idle_us
            t_us += idle_us
        u = rng.next_f64()
        tenant = 0 if u < 0.55 else (1 if u < 0.85 else 2)
        counts[tenant] += 1
        v = rng.next_f64()
        if v < 0.70:
            length = rng.range(4, 32)
        elif v < 0.95:
            length = rng.range(32, 96)
        else:
            length = rng.range(96, 128)
        lines.append(f"{int(t_us)} {tenant} {length}")

    span_s = int(lines[-1].split()[0]) / 1e6 if lines else 0.0
    mean_qps = args.requests / span_s if span_s > 0 else 0.0
    with open(args.out, "w") as f:
        f.write(f"#altup-trace v1 seed={args.seed:#x}\n")
        f.write(
            f"# {args.requests} requests over {span_s:.3f} s "
            f"(mean {mean_qps:.0f} req/s offered; peak {args.peak_qps:.0f}), "
            f"bursts {args.burst_ms:.0f} ms on / {args.idle_ms:.0f} ms off\n"
        )
        f.write(
            f"# tenants: 0=free x{counts[0]}, 1=silver x{counts[1]}, "
            f"2=gold x{counts[2]}; lengths 70% [4,32) / 25% [32,96) / 5% [96,128)\n"
        )
        f.write("# arrival_us tenant prompt_len\n")
        f.write("\n".join(lines) + "\n")
    print(
        f"wrote {args.out}: {args.requests} requests, span {span_s:.3f} s, "
        f"mean offered {mean_qps:.0f} req/s, tenants {counts}"
    )


if __name__ == "__main__":
    main()
