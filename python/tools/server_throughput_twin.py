"""Threaded twin of `rust/benches/server_throughput.rs`.

Mirrors the Rust serving bench 1:1 — same SplitMix64 workload stream
(prompt lengths AND token values, so the hash-sampled EOS positions
match bit-for-bit), same bucket ladder (`runtime::session::bucket_for`),
same router policy (group by bucket, flush on full batch or expired
window), same replica-pool semantics, and the same sim cost model:

- monolithic `decode_step` batch: ``token_ns * batch_size * bucket``
  prefill plus ``dec_len * (dstep_ns + dtoken_ns * batch_size)`` decode
  (every row pays the full dec_len — no early exit);
- split path: per admission group ``dstep_ns + token_ns * rows *
  bucket`` (varlen-style prefill), per fused decode iteration
  ``dstep_ns + dtoken_ns * slots`` over the static slot geometry, rows
  retiring at their sampled EOS.

This lets the serving-policy numbers (continuous vs batch QPS, p95,
early-exit savings, occupancy) be measured on machines without a cargo
toolchain or a PJRT backend. The Rust bench is the canonical producer
of BENCH_server_throughput.json; running it overwrites this twin's
output (the ``producer`` field records which one wrote the file).

Usage: python3 python/tools/server_throughput_twin.py [out.json]
"""

import json
import queue
import sys
import threading
import time
from collections import deque

MASK = (1 << 64) - 1

BATCH_SIZE = 8
ENC_LEN = 128
DEC_LEN = 48
VOCAB = 512
TOKEN_NS = 20000   # mirrors SimSpec::new's ALTUP_SIM_TOKEN_NS default
DTOKEN_NS = 20000  # ALTUP_SIM_DTOKEN_NS default (= token_ns)
DSTEP_NS = 50000   # ALTUP_SIM_DSTEP_NS default
WINDOW_S = 0.002
REQUESTS = 384
CLIENTS = 32
MIN_BUCKET = 8


class Rng:
    """SplitMix64, matching rust/src/util/rng.rs bit-for-bit."""

    def __init__(self, seed):
        self.state = (seed + 0x9E3779B97F4A7C15) & MASK

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def range(self, lo, hi):
        return lo + ((self.next_u64() * (hi - lo)) >> 64)


def bucket_for(length, enc_len):
    """Mirror of runtime::session::bucket_for."""
    if length >= enc_len:
        return enc_len
    b = MIN_BUCKET
    while b < enc_len:
        if length <= b:
            return b
        b <<= 1
    return enc_len


def sim_row_hash(tokens):
    """FNV-1a over the prompt tokens (coordinator::server::sim_row_hash)."""
    h = 0xCBF29CE484222325
    for t in tokens:
        h = ((h ^ (t & 0xFFFFFFFF)) * 0x00000100000001B3) & MASK
    return h


def sim_gen_len(h, dec_len):
    """Hash-sampled generation length in [1, dec_len] (sim_gen_len)."""
    x = h ^ (h >> 33)
    x = (x * 0xFF51AFD7ED558CCD) & MASK
    x ^= x >> 29
    return 1 + (x % max(dec_len, 1))


def mixed_prompts(n, enc_len, vocab, seed):
    """Mirror of the bench's mixed_prompts draws: (length, gen_len)."""
    rng = Rng(seed)
    out = []
    for _ in range(n):
        if rng.next_f64() < 0.7:
            length = rng.range(4, max(enc_len // 4, 5))
        else:
            length = rng.range(enc_len // 2, enc_len)
        tokens = [rng.range(1, vocab) for _ in range(length)]
        out.append((length, sim_gen_len(sim_row_hash(tokens), DEC_LEN)))
    return out


def nsleep(ns):
    """Precise simulated-device wait. This container's kernel rounds
    every ``time.sleep`` up to ~1 ms, which would tax the continuous
    path's many sub-ms fused decode steps 5x while leaving the batch
    path's few ~20 ms sleeps untouched — so coarse-sleep the bulk and
    yield-spin the final stretch instead (``time.sleep(0)`` releases
    the GIL each probe)."""
    end = time.perf_counter_ns() + ns
    while True:
        rem = end - time.perf_counter_ns()
        if rem <= 0:
            return
        if rem > 1_500_000:
            time.sleep((rem - 1_200_000) / 1e9)
        else:
            time.sleep(0)


def percentile(samples, p):
    if not samples:
        return 0.0
    v = sorted(samples)
    idx = round((p / 100.0) * (len(v) - 1))
    return v[min(idx, len(v) - 1)]


class Stats:
    def __init__(self):
        self.requests = 0
        self.batches = 0
        self.total_fill = 0
        self.prompt_tokens = 0
        self.executed_tokens = 0
        self.tokens_generated = 0
        self.tokens_saved = 0
        self.decode_steps = 0
        self.occupancy_sum = 0
        self.latency_ms = []
        self.token_ms = []
        self.lock = threading.Lock()

    def waste_ratio(self):
        if self.executed_tokens == 0:
            return 0.0
        return 1.0 - self.prompt_tokens / self.executed_tokens

    def mean_fill(self):
        return self.total_fill / self.batches if self.batches else 0.0

    def early_exit_ratio(self):
        budget = self.tokens_saved + self.tokens_generated
        return self.tokens_saved / budget if budget else 0.0

    def mean_occupancy(self):
        return self.occupancy_sum / self.decode_steps if self.decode_steps else 0.0

    def note_response(self, latency_s, generated, saved, prompt):
        self.latency_ms.append(latency_s * 1e3)
        self.token_ms.append(latency_s * 1e3 / max(generated, 1))
        self.tokens_generated += generated
        self.tokens_saved += saved
        self.prompt_tokens += prompt
        self.requests += 1


def run_config(workload, replicas, bucketed, continuous, slots=0):
    req_q = queue.Queue()
    # Bounded job queue = backpressure, mirroring the Rust router: full
    # groups ship with a blocking put; due-but-partial groups ship
    # best-effort and otherwise keep accumulating while replicas are
    # busy.
    job_q = queue.Queue(maxsize=max(replicas, 1))
    stats = Stats()
    n_clients = CLIENTS
    slots_n = slots if slots > 0 else BATCH_SIZE

    def router():
        # bucket -> list of (t0, admitted, reply_q, length, gen_len);
        # latency is reported from the client-side t0, the batch-window
        # deadline runs from admission (mirrors the Rust router).
        groups = {}
        live_clients = n_clients
        disconnected = False
        while not (disconnected and not groups):
            now = time.monotonic()
            due_unsent = False
            for bucket in list(groups.keys()):
                group = groups[bucket]
                full = len(group) >= BATCH_SIZE
                due = now >= group[0][1] + WINDOW_S
                if full or disconnected:
                    job_q.put((bucket, groups.pop(bucket)))
                elif due:
                    g = groups.pop(bucket)
                    try:
                        job_q.put_nowait((bucket, g))
                    except queue.Full:
                        groups[bucket] = g
                        due_unsent = True
            if disconnected:
                continue
            msg = None
            if not groups:
                m = req_q.get()
                if m is None:
                    live_clients -= 1
                    if live_clients == 0:
                        disconnected = True
                else:
                    msg = m
            else:
                if due_unsent:
                    wait = WINDOW_S
                else:
                    oldest = min(g[0][1] for g in groups.values())
                    wait = oldest + WINDOW_S - time.monotonic()
                if wait > 0:
                    try:
                        m = req_q.get(timeout=wait)
                        if m is None:
                            live_clients -= 1
                            if live_clients == 0:
                                disconnected = True
                        else:
                            msg = m
                    except queue.Empty:
                        pass
            if msg is not None:
                t0, reply, length, gen_len = msg
                bucket = bucket_for(length, ENC_LEN) if bucketed else ENC_LEN
                groups.setdefault(bucket, []).append(
                    (t0, time.monotonic(), reply, length, gen_len)
                )
        for _ in range(max(replicas, 1)):
            job_q.put(None)

    def replica_batch():
        # Run-to-completion decode_step loop: full-geometry prefill plus
        # every decode step for every row, early exit or not.
        while True:
            job = job_q.get()
            if job is None:
                break
            bucket, group = job
            ns = TOKEN_NS * BATCH_SIZE * bucket + DEC_LEN * (
                DSTEP_NS + DTOKEN_NS * BATCH_SIZE
            )
            nsleep(ns)
            now = time.monotonic()
            with stats.lock:
                stats.batches += 1
                stats.total_fill += len(group)
                stats.executed_tokens += BATCH_SIZE * bucket
                for t0, _adm, _reply, length, gen_len in group:
                    stats.note_response(now - t0, gen_len, 0, min(length, bucket))
            for _t0, _adm, reply, _length, _gen in group:
                reply.put(True)

    def replica_cont():
        # Slot-based continuous batching, mirroring serve_continuous:
        # admit pending requests into free slots (one varlen prefill per
        # same-bucket group), one fused decode iteration over the slot
        # geometry, retire rows at their sampled EOS.
        pending = deque()  # (bucket, t0, reply, length, gen_len)
        active = [None] * slots_n  # (t0, reply, length, gen_len, emitted, bucket)
        router_gone = False

        def stash(job):
            bucket, group = job
            for t0, _adm, reply, length, gen_len in group:
                pending.append((bucket, t0, reply, length, gen_len))

        while True:
            n_live = sum(1 for a in active if a is not None)
            if not router_gone:
                if n_live == 0 and not pending:
                    job = job_q.get()
                    if job is None:
                        router_gone = True
                    else:
                        stash(job)
                while len(pending) < slots_n and not router_gone:
                    try:
                        job = job_q.get_nowait()
                    except queue.Empty:
                        break
                    if job is None:
                        router_gone = True
                    else:
                        stash(job)
            # Admit same-bucket runs into free slots.
            free = deque(i for i, a in enumerate(active) if a is None)
            while free and pending:
                bucket = pending[0][0]
                group = []
                ids = []
                while (
                    pending
                    and pending[0][0] == bucket
                    and free
                    and len(group) < BATCH_SIZE
                ):
                    _b, t0, reply, length, gen_len = pending.popleft()
                    sid = free.popleft()
                    active[sid] = [t0, reply, length, gen_len, 0, bucket]
                    group.append(sid)
                    ids.append(sid)
                if not group:
                    break
                nsleep(DSTEP_NS + TOKEN_NS * len(group) * bucket)
                with stats.lock:
                    stats.batches += 1
                    stats.total_fill += len(group)
                    stats.executed_tokens += len(group) * bucket
            n_live = sum(1 for a in active if a is not None)
            if n_live == 0:
                if router_gone and not pending:
                    break
                continue
            # One fused decode iteration over the whole slot geometry.
            nsleep(DSTEP_NS + DTOKEN_NS * slots_n)
            now = time.monotonic()
            with stats.lock:
                stats.decode_steps += 1
                stats.occupancy_sum += n_live
            for s, act in enumerate(active):
                if act is None:
                    continue
                act[4] += 1
                if act[4] >= act[3] or act[4] >= DEC_LEN:
                    t0, reply, length, gen_len, emitted, bucket = act
                    active[s] = None
                    with stats.lock:
                        stats.note_response(
                            now - t0, emitted, DEC_LEN - emitted, min(length, bucket)
                        )
                    reply.put(True)

    def client(c):
        for length, gen_len in workload[c::n_clients]:
            reply = queue.SimpleQueue()
            req_q.put((time.monotonic(), reply, length, gen_len))
            reply.get()
        req_q.put(None)  # this client is done

    target = replica_cont if continuous else replica_batch
    threads = [threading.Thread(target=router, name="router")]
    threads += [
        threading.Thread(target=target, name=f"replica-{i}")
        for i in range(max(replicas, 1))
    ]
    t_start = time.monotonic()
    client_threads = [
        threading.Thread(target=client, args=(c,), name=f"client-{c}")
        for c in range(n_clients)
    ]
    for t in threads + client_threads:
        t.start()
    for t in client_threads:
        t.join()
    for t in threads:
        t.join()
    wall = time.monotonic() - t_start
    qps = len(workload) / max(wall, 1e-9)
    # Batch-mode note_response runs under the batch's `now`; requests
    # counted there. Continuous counts at retire. Either way requests ==
    # workload size when every reply arrived.
    assert stats.requests == len(workload), (stats.requests, len(workload))
    return qps, stats


def row(mode, replicas, qps, stats):
    return {
        "mode": mode,
        "replicas": replicas,
        "qps": round(qps, 1),
        "mean_fill": round(stats.mean_fill(), 3),
        "waste_ratio": round(stats.waste_ratio(), 4),
        "prompt_tokens": stats.prompt_tokens,
        "executed_tokens": stats.executed_tokens,
        "batches": stats.batches,
        "tokens_generated": stats.tokens_generated,
        "early_exit_saved_ratio": round(stats.early_exit_ratio(), 4),
        "decode_steps": stats.decode_steps,
        "mean_occupancy": round(stats.mean_occupancy(), 3),
        "token_ms": round(
            sum(stats.token_ms) / len(stats.token_ms) if stats.token_ms else 0.0, 3
        ),
        "p50_ms": round(percentile(stats.latency_ms, 50), 2),
        "p95_ms": round(percentile(stats.latency_ms, 95), 2),
        "p99_ms": round(percentile(stats.latency_ms, 99), 2),
    }


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_server_throughput.json"
    workload = mixed_prompts(REQUESTS, ENC_LEN, VOCAB, 0x5E0A11)

    base_qps, base_stats = run_config(workload, 1, bucketed=False, continuous=False)
    print(f"baseline full-length x1: {base_qps:.1f} qps, "
          f"waste {base_stats.waste_ratio() * 100:.1f}%, "
          f"p95 {percentile(base_stats.latency_ms, 95):.2f} ms")

    rows = []
    by = {}
    for replicas in (1, 2, 4):
        for mode, continuous in (("batch", False), ("cont", True)):
            qps, stats = run_config(
                workload, replicas, bucketed=True, continuous=continuous
            )
            by[(mode, replicas)] = (qps, percentile(stats.latency_ms, 95))
            rows.append(row(mode, replicas, qps, stats))
            print(
                f"{mode} x{replicas}: {qps:.1f} qps, fill {stats.mean_fill():.2f}, "
                f"waste {stats.waste_ratio() * 100:.1f}%, "
                f"occup {stats.mean_occupancy():.2f}, "
                f"saved {stats.early_exit_ratio() * 100:.1f}%, "
                f"p50 {percentile(stats.latency_ms, 50):.2f} ms, "
                f"p95 {percentile(stats.latency_ms, 95):.2f} ms"
            )

    bq1, bp1 = by[("batch", 1)]
    cq1, cp1 = by[("cont", 1)]
    cq4, _ = by[("cont", 4)]
    qps_ratio = cq1 / bq1 if bq1 else 0.0
    p95_red = 1.0 - cp1 / bp1 if bp1 else 0.0
    print(f"continuous vs batch @x1: {qps_ratio:.2f}x qps, "
          f"p95 {bp1:.2f} -> {cp1:.2f} ms ({p95_red * 100:.1f}% lower), "
          f"cont scaling x4/x1 = {cq4 / cq1 if cq1 else 0.0:.2f}x")

    doc = {
        "bench": "server_throughput",
        "engine": "sim",
        "workload": {
            "requests": REQUESTS,
            "clients": CLIENTS,
            "batch_size": BATCH_SIZE,
            "enc_len": ENC_LEN,
            "dec_len": DEC_LEN,
            "slots": 0,
            "mix": "70% short [4, enc/4), 30% long [enc/2, enc)",
            "eos": "generation length hash-sampled uniform in [1, dec_len]",
            "batch_window_ms": WINDOW_S * 1e3,
        },
        "baseline_full_length": row("batch-unbucketed", 1, base_qps, base_stats),
        "configs": rows,
        "cont_over_batch_x1": {
            "qps_ratio": round(qps_ratio, 3),
            "p95_reduction": round(p95_red, 3),
        },
        "qps_scaling_x4_over_x1": round(cq4 / cq1 if cq1 else 0.0, 3),
        "producer": "python/tools/server_throughput_twin.py "
                    "(threaded twin; re-run `cargo bench --bench server_throughput -- --json` "
                    "on a cargo-enabled machine to overwrite with the Rust measurement)",
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
