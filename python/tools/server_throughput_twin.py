"""Threaded twin of `rust/benches/server_throughput.rs`.

Mirrors the Rust serving bench 1:1 — same SplitMix64 workload stream
(prompt lengths AND token values, so the hash-sampled EOS positions
match bit-for-bit), same bucket ladder (`runtime::session::bucket_for`),
same router policy (group by bucket, flush on full batch or expired
window), same replica-pool semantics, the same sim cost model, and the
same §L7 fault model (deterministic replica kill by engine-call count,
supervisor requeue of the crashed replica's in-flight requests with a
bounded per-request retry budget, replacement respawn within a restart
budget, terminal responses for every request):

- monolithic `decode_step` batch: ``token_ns * batch_size * bucket``
  prefill plus ``dec_len * (dstep_ns + dtoken_ns * batch_size)`` decode
  (every row pays the full dec_len — no early exit);
- split path: per admission group ``dstep_ns + token_ns * rows *
  bucket`` (varlen-style prefill), per fused decode iteration
  ``dstep_ns + dtoken_ns * slots`` over the static slot geometry, rows
  retiring at their sampled EOS;
- degraded A/B: cont x4 with one replica killed mid-run vs the healthy
  cont x4 — the acceptance bar is degraded QPS >= 65% of healthy;
- §L8 speculative decoding: per continuous iteration, γ draft-model
  steps (``γ * (draft_step_ns + draft_token_ns * slots)``) plus ONE
  fused full-model verify (costed like a decode_token step), each live
  slot advancing by its hash-sampled accepted prefix + 1 correction
  token (``sim_accept_len``, the leading run of per-position coins
  under ``ACCEPT_RATE`` — bit-for-bit the Rust sampler). The spec A/B
  runs cont x1 spec vs cont x1 plain on a decode-heavy dec_len=128
  workload; the bar is >= 1.4x decode-token throughput (tokens/s);
- §L9 paged decode state: each continuous replica can serve out of a
  fixed page pool (``PagePool``/``PrefixCache`` here mirror
  ``runtime::pages`` — LIFO free list, refcounts, chained chunk
  hashes, LRU eviction of unpinned cache pages) with pool-aware
  admission (shed / evict / stall, in that order) and prefill cost
  ``dstep_ns + token_ns * (rows * bucket - prefix_tokens_saved)``.
  Two A/Bs: equal-pool-memory slots-per-replica (paged vs monolithic,
  bar >= 1.5x mean occupancy) and a tenant-skewed shared-prefix
  workload (bar >= 40% prefill tokens saved at equal output tokens).

- §L10 multi-tenant QoS: a mirror of ``coordinator::admission`` (per-
  tenant token buckets, an overload door for the lowest class, an
  SLO-aware wait gate over an EWMA'd service rate, capped priority
  queues with preemption, weighted priority release, and the 300/500 ms
  pressure/calm degradation ladder driving autoscale spawns) sits in
  front of the router when tenants are configured; the checked-in
  burst trace (``rust/benches/traces/burst_mix.trace``) is replayed
  open-loop — same header-seeded token stream as the Rust loader —
  through a paged cont x2 fleet with a mid-burst replica kill plus
  page-pool pressure, against a clean QoS run and a QoS-off chaos run.
  Bars: every request terminal, gold p95 within SLO under chaos,
  >= 80% of sheds on the lowest class, chaos goodput >= 0.8x clean.

- §L11 rolling weight swap: a mirror of ``coordinator::deploy`` — one
  replica drained at a time (the §L7 drain lever, scoped to a single
  target), the successor rejoining as a canary that must answer a
  pinned probe set at exact token parity with the old version before
  it serves ANY live traffic, then survive a probation window's error
  and p95-vs-fleet-EWMA gates; a failing canary is abandoned and the
  drained slot reloads the old version (automatic rollback). Crash
  respawns mid-rollout land on the DECIDED version; rollout-owned
  exits (drains, abandoned canaries) spend no §L7 restart budget; a
  per-version ledger partitions the global request/failure counters.
  Four arms on the same burst trace, swap fired at 25% of the span:
  no-swap, rolling upgrade, rolling + replica kill, and a wrong-token
  bad version. Bars: rolling + chaos arms complete with zero failed
  requests at >= 0.85x no-swap goodput, the bad arm rolls back with
  zero canary passes, and every arm's response-token hash matches the
  no-swap arm (rollback pins old-version outputs).

- §L13 span tracing: the twin mirrors ``coordinator::trace``'s
  attribution protocol — per-request phase boundaries (router pop,
  QoS release, prefill start/end, retirement) telescope over
  [t0, retirement], so the five top-level phase durations sum to each
  request's e2e latency exactly. Three A/Bs mirror the bench's trace
  section: mark-recording overhead (tracing-on >= 0.97x untraced QPS),
  burst-replay phase attribution QoS-on vs QoS-off (all requests and
  the slowest-5% tail), and a tp2 slow-link pair where AltUp's narrow
  sync is a smaller allreduce share of engine time than dense.

This lets the serving-policy numbers (continuous vs batch QPS, p95,
early-exit savings, occupancy, degraded-mode QPS) be measured on
machines without a cargo toolchain or a PJRT backend. The Rust bench is
the canonical producer of BENCH_server_throughput.json; running it
overwrites this twin's output (the ``producer`` field records which one
wrote the file).

Usage: python3 python/tools/server_throughput_twin.py [out.json]
"""

import json
import queue
import sys
import threading
import time
from collections import deque

MASK = (1 << 64) - 1

BATCH_SIZE = 8
ENC_LEN = 128
DEC_LEN = 48
VOCAB = 512
TOKEN_NS = 20000   # mirrors SimSpec::new's ALTUP_SIM_TOKEN_NS default
DTOKEN_NS = 20000  # ALTUP_SIM_DTOKEN_NS default (= token_ns)
DSTEP_NS = 50000   # ALTUP_SIM_DSTEP_NS default
WINDOW_S = 0.002
REQUESTS = 384
CLIENTS = 32
MIN_BUCKET = 8
MAX_RETRIES = 2    # ServerOptions::max_retries default
RESTARTS = 2       # ALTUP_REPLICA_RESTARTS default
KILL_REPLICA = 1   # degraded A/B: which replica the fault kills
KILL_AFTER = 40    # ...on which engine call (mirrors bench --kill-after)
# §L8 draft cost/acceptance model (SimDraftSpec defaults) + the spec
# A/B shape (bench --spec-gamma / --spec-dec-len defaults).
DRAFT_TOKEN_NS = DTOKEN_NS // 8   # ALTUP_SIM_DRAFT_TOKEN_NS default
DRAFT_STEP_NS = DSTEP_NS // 4     # ALTUP_SIM_DRAFT_STEP_NS default
ACCEPT_RATE = 0.8                 # ALTUP_SIM_ACCEPT_RATE default
SPEC_GAMMA = 4
SPEC_DEC_LEN = 128
# §L9 paged-pool A/B shape (bench --page-size and the prefix workload).
PAGE_SIZE = 16                    # ALTUP_PAGE_SIZE default
PREFIX_TENANTS = 4
PREFIX_HEADER = 96                # 6 full pages of shared system prompt
PREFIX_POOL_PAGES = 128
PREFIX_SLOTS = 8
# §L10 trace-driven QoS + chaos A/B shape (mirrors the bench defaults:
# tenant spec string, paged cont x2 fleet, replica 1 killed mid-burst
# with 25% of the page pool withheld, autoscale budget 2).
QOS_TRACE = "rust/benches/traces/burst_mix.trace"
QOS_TENANT_SPEC = "free:0:1:250:40:0;silver:1:2:0:0:4000;gold:2:4:0:0:1500"
QOS_TENANTS = [
    {"name": "free", "priority": 0, "weight": 1, "rate": 250.0, "burst": 40.0,
     "slo_ms": 0},
    {"name": "silver", "priority": 1, "weight": 2, "rate": 0.0, "burst": 0.0,
     "slo_ms": 4000},
    {"name": "gold", "priority": 2, "weight": 4, "rate": 0.0, "burst": 0.0,
     "slo_ms": 1500},
]
QOS_POOL_PAGES = 96
QOS_POOL_RESERVE = 0.25
QOS_KILL_CALL = 600
QOS_QUEUE_CAP = 1024
QOS_AUTOSCALE = 2
# Overload-ladder clock (admission.rs constants).
OVERLOAD_HOLD_S = 0.3
CALM_HOLD_S = 0.5
RATE_WINDOW_S = 0.25
RATE_ALPHA = 0.3
# §L11 rolling-swap A/B shape (mirrors the bench swap_opts: paged cont
# x2 fleet with a pool roomy enough that §L9 pressure can never fail a
# canary, rollout fired at 25% of the trace span, successor 0.9x cost).
SWAP_COST_MULT = 0.9
SWAP_KILL_CALL = 220
SWAP_POOL_PAGES = 192
SWAP_PROBATION = 12            # DeployOptions::probation
SWAP_PROBATION_S = 0.3         # DeployOptions::probation_ms
SWAP_PROBES = 2                # DeployOptions::probes
SWAP_MAX_ERR = 0.25            # DeployOptions::max_err
SWAP_LAT_FACTOR = 8.0          # DeployOptions::lat_factor
SWAP_HOLD_S = 15.0             # DeployOptions::hold_ms
BAD_VERSION_SALT = 0x0BAD5EED0BAD5EED  # coordinator::server constant
# §L12 TP-vs-DP crossover A/B shape (bench --tp / --tp-kill-call
# defaults plus the CollectiveSpec knobs the bench pins per point).
TP = 2
TP_KILL_CALL = 40
TP_DMODEL = 1024
TP_ELEM_BYTES = 2
TP_LATENCY_NS = 500
TP_SYNCS_PER_STEP = 12
TP_PARTITIONED_FRAC = 0.85
TP_LIGHT_CLIENTS = 1


class Rng:
    """SplitMix64, matching rust/src/util/rng.rs bit-for-bit."""

    def __init__(self, seed):
        self.state = (seed + 0x9E3779B97F4A7C15) & MASK

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def range(self, lo, hi):
        return lo + ((self.next_u64() * (hi - lo)) >> 64)


def bucket_for(length, enc_len):
    """Mirror of runtime::session::bucket_for."""
    if length >= enc_len:
        return enc_len
    b = MIN_BUCKET
    while b < enc_len:
        if length <= b:
            return b
        b <<= 1
    return enc_len


def sim_row_hash(tokens):
    """FNV-1a over the prompt tokens (coordinator::server::sim_row_hash)."""
    h = 0xCBF29CE484222325
    for t in tokens:
        h = ((h ^ (t & 0xFFFFFFFF)) * 0x00000100000001B3) & MASK
    return h


def sim_mix64(x):
    """murmur3-style finalizer (coordinator::server::sim_mix)."""
    x ^= x >> 33
    x = (x * 0xFF51AFD7ED558CCD) & MASK
    return x ^ (x >> 29)


def sim_gen_len(h, dec_len):
    """Hash-sampled generation length in [1, dec_len] (sim_gen_len)."""
    return 1 + (sim_mix64(h) % max(dec_len, 1))


def sim_accept_len(h, pos, gamma, rate):
    """§L8 acceptance sampler (coordinator::server::sim_accept_len,
    bit-for-bit): the accepted prefix is the leading run of per-position
    hash coins landing under ``rate``."""
    n = 0
    while n < gamma:
        x = sim_mix64(h ^ (((pos + n) * 0xD1B54A32D192ED03) & MASK))
        if (x >> 11) * (1.0 / (1 << 53)) >= rate:
            break
        n += 1
    return n


def pages_for(tokens, page_size):
    """Mirror of runtime::pages::pages_for (round up)."""
    ps = max(page_size, 1)
    return (tokens + ps - 1) // ps


def chunk_hashes(tokens, page_size):
    """Chained FNV-1a page-chunk hashes, bit-for-bit
    runtime::pages::chunk_hashes: entry k covers the first
    (k+1)*page_size tokens; the trailing partial chunk is never
    hashed."""
    ps = max(page_size, 1)
    out = []
    h = 0xCBF29CE484222325
    for i in range((len(tokens) // ps) * ps):
        h = ((h ^ (tokens[i] & 0xFFFFFFFF)) * 0x00000100000001B3) & MASK
        if (i + 1) % ps == 0:
            out.append(h)
    return out


class PagePool:
    """Mirror of runtime::pages::PagePool: refcounted pages over a
    LIFO free list (first alloc hands out page 0)."""

    def __init__(self, page_size, capacity):
        self.page_size = max(page_size, 1)
        self.capacity = capacity
        self.refs = [0] * capacity
        self.free = list(range(capacity - 1, -1, -1))

    def free_pages(self):
        return len(self.free)

    def used_pages(self):
        return self.capacity - len(self.free)

    def alloc(self):
        page = self.free.pop()
        self.refs[page] = 1
        return page

    def retain(self, page):
        assert self.refs[page] > 0, f"retain of free page {page}"
        self.refs[page] += 1

    def release(self, page):
        assert self.refs[page] > 0, f"double free of page {page}"
        self.refs[page] -= 1
        if self.refs[page] == 0:
            self.free.append(page)


class PrefixCache:
    """Mirror of runtime::pages::PrefixCache: chunk hash -> page, with
    LRU eviction (least recent first) of unpinned entries (refcount 1 —
    only the cache holds the page)."""

    def __init__(self):
        self.entries = {}
        self.order = []  # recency order, least recent first

    def match_len(self, hashes):
        n = 0
        for h in hashes:
            if h not in self.entries:
                break
            n += 1
        return n

    def hit(self, h):
        self.order.remove(h)
        self.order.append(h)
        return self.entries[h]

    def insert(self, pool, h, page):
        if h in self.entries:
            return
        pool.retain(page)
        self.entries[h] = page
        self.order.append(h)

    def evict_lru(self, pool):
        for h in self.order:
            page = self.entries[h]
            if pool.refs[page] == 1:
                self.order.remove(h)
                del self.entries[h]
                pool.release(page)
                return True
        return False


def mixed_prompts(n, enc_len, vocab, seed):
    """Mirror of the bench's mixed_prompts draws: (length, row_hash,
    chunk_hashes). Generation lengths derive from the hash per run
    (`sim_gen_len(h, dec_len)`), so one workload serves every dec_len
    variant; chunk hashes (at PAGE_SIZE) feed the §L9 prefix cache."""
    rng = Rng(seed)
    out = []
    for _ in range(n):
        if rng.next_f64() < 0.7:
            length = rng.range(4, max(enc_len // 4, 5))
        else:
            length = rng.range(enc_len // 2, enc_len)
        tokens = [rng.range(1, vocab) for _ in range(length)]
        out.append((length, sim_row_hash(tokens), chunk_hashes(tokens, PAGE_SIZE)))
    return out


def shared_prefix_prompts(n, enc_len, vocab, seed, tenants, header_len):
    """Mirror of the bench's shared_prefix_prompts draws: each request
    is one of ``tenants`` fixed page-aligned system-prompt headers plus
    a short distinct tail (uniform in [8, 32)) — the tenant-skewed
    workload where cross-request prefix caching pays."""
    rng = Rng(seed)
    headers = [
        [rng.range(1, vocab) for _ in range(header_len)] for _ in range(tenants)
    ]
    out = []
    for _ in range(n):
        t = rng.range(0, tenants)
        tail = rng.range(8, 32)
        tokens = headers[t] + [rng.range(1, vocab) for _ in range(tail)]
        out.append((len(tokens), sim_row_hash(tokens), chunk_hashes(tokens, PAGE_SIZE)))
    return out


def load_trace(path, vocab, limit=0):
    """Mirror of the bench's §L10 trace loader: parse an
    ``#altup-trace v1`` file and materialize prompt tokens from the
    header seed — one shared SplitMix64 stream, ``prompt_len`` draws
    per line in file order, bit-identical to the Rust side. Returns
    (arrival_us, tenant, length, row_hash, chunk_hashes) tuples."""
    rows = []
    seed = 0x51C0DE
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if line.startswith("#"):
                for tok in line[1:].split():
                    if tok.startswith("seed="):
                        v = tok[5:]
                        v = v[2:] if v.startswith("0x") else v
                        seed = int(v, 16)
                continue
            a, t, l = line.split()[:3]
            rows.append((int(a), int(t), int(l)))
    if limit:
        rows = rows[:limit]
    rng = Rng(seed)
    out = []
    for a, t, l in rows:
        tokens = [rng.range(1, vocab) for _ in range(l)]
        out.append((a, t, l, sim_row_hash(tokens), chunk_hashes(tokens, PAGE_SIZE)))
    return out


class Admission:
    """Mirror of ``rust/src/coordinator/admission.rs``: the request
    path is token bucket -> overload door (lowest class, level >= 1) ->
    SLO wait gate (EWMA'd service rate) -> capped priority queues with
    preemption of the newest lower-class entry; release drains the
    highest priority class first, weighted within a class by accrued
    served/weight cost. The ladder escalates one rung per 300 ms of
    sustained backlog above 2x the fleet's capacity hint and
    de-escalates per 500 ms of calm. Request records are the router's
    10-tuples; index 8 is the tenant, 9 the deadline (stamped here from
    the tenant SLO)."""

    def __init__(self, tenants, cap, now):
        self.tenants = tenants
        self.buckets = [
            t["burst"] if t["burst"] > 0 else max(t["rate"], 1.0) for t in tenants
        ]
        self.queues = [deque() for _ in tenants]
        self.served = [0] * len(tenants)
        self.queued = 0
        self.cap = max(cap, 1)
        self.lowest = min(t["priority"] for t in tenants)
        self.last_refill = now
        self.service_rate = 0.0
        self.window_start = now
        self.window_released = 0
        self.level = 0
        self.pressure_since = None
        self.calm_since = None

    def _refill(self, now):
        dt = max(now - self.last_refill, 0.0)
        self.last_refill = now
        for i, t in enumerate(self.tenants):
            if t["rate"] > 0:
                cap = t["burst"] if t["burst"] > 0 else max(t["rate"], 1.0)
                self.buckets[i] = min(self.buckets[i] + t["rate"] * dt, cap)

    def wait_s(self, depth):
        return depth / self.service_rate if self.service_rate > 0 else 0.0

    def offer(self, rec, now, downstream):
        """Returns ("queued", None) if the record was parked, or
        ("shed", record) — the record to answer with a failure (the
        arrival itself, or a preempted lower-class victim while the
        arrival takes its queue slot)."""
        self._refill(now)
        t = min(rec[8], len(self.tenants) - 1)
        spec = self.tenants[t]
        prio = spec["priority"]
        if rec[9] is None and spec["slo_ms"] > 0:
            rec = rec[:9] + (rec[0] + spec["slo_ms"] / 1e3,)
        if spec["rate"] > 0:
            if self.buckets[t] < 1.0:
                return "shed", rec
            self.buckets[t] -= 1.0
        depth = self.queued + downstream
        if self.level >= 1 and prio == self.lowest and depth > self.cap // 4:
            return "shed", rec
        if rec[9] is not None and now + self.wait_s(depth) >= rec[9]:
            return "shed", rec
        if self.queued >= self.cap:
            victim = self._preempt_below(prio)
            if victim is not None:
                self.queues[t].append((rec, prio))
                self.queued += 1
                return "shed", victim
            return "shed", rec
        self.queues[t].append((rec, prio))
        self.queued += 1
        return "queued", None

    def _preempt_below(self, prio):
        best = None  # (victim priority, tenant index)
        for i, q in enumerate(self.queues):
            if q and q[-1][1] < prio and (best is None or q[-1][1] < best[0]):
                best = (q[-1][1], i)
        if best is None:
            return None
        rec, _ = self.queues[best[1]].pop()
        self.queued -= 1
        return rec

    def release(self, room):
        out = []
        for _ in range(room):
            t = self._next_tenant()
            if t is None:
                break
            rec, _ = self.queues[t].popleft()
            self.queued -= 1
            self.served[t] += 1
            self.window_released += 1
            out.append(rec)
        return out

    def _next_tenant(self):
        top = None
        for i, t in enumerate(self.tenants):
            if self.queues[i]:
                top = t["priority"] if top is None else max(top, t["priority"])
        if top is None:
            return None
        best = None  # (cost, tenant index)
        for i, t in enumerate(self.tenants):
            if self.queues[i] and t["priority"] == top:
                cost = self.served[i] / max(t["weight"], 1)
                if best is None or cost < best[0]:
                    best = (cost, i)
        return best[1]

    def take_expired(self, now):
        out = []
        for i, q in enumerate(self.queues):
            keep = deque()
            for rec, p in q:
                if rec[9] is not None and now >= rec[9]:
                    self.queued -= 1
                    out.append(rec)
                else:
                    keep.append((rec, p))
            self.queues[i] = keep
        return out

    def tick(self, now, downstream, capacity_hint):
        """Overload-controller heartbeat; returns ladder actions. The
        γ rung is a no-op here (the QoS runs are plain-decode), so
        levels >= 2 ask for autoscale like the Rust controller does
        when no draft model is configured."""
        actions = []
        if now - self.window_start >= RATE_WINDOW_S:
            dt = max(now - self.window_start, 1e-9)
            if self.window_released > 0 or self.service_rate > 0:
                inst = self.window_released / dt
                self.service_rate = (
                    self.service_rate * (1 - RATE_ALPHA) + inst * RATE_ALPHA
                    if self.service_rate > 0
                    else inst
                )
            self.window_start = now
            self.window_released = 0
        depth = self.queued + downstream
        hint = max(capacity_hint, 1)
        if depth > 2 * hint:
            self.calm_since = None
            if self.pressure_since is None:
                self.pressure_since = now
            if now - self.pressure_since >= OVERLOAD_HOLD_S:
                self.pressure_since = now
                self.level += 1
                if self.level >= 2:
                    actions.append("scale_up")
        elif depth < hint // 2 + 1:
            self.pressure_since = None
            if self.calm_since is None:
                self.calm_since = now
            if now - self.calm_since >= CALM_HOLD_S:
                self.calm_since = now
                if self.level == 0:
                    actions.append("scale_down")
                self.level = max(self.level - 1, 0)
        else:
            self.pressure_since = None
            self.calm_since = None
        return actions


def nsleep(ns):
    """Precise simulated-device wait. This container's kernel rounds
    every ``time.sleep`` up to ~1 ms, which would tax the continuous
    path's many sub-ms fused decode steps 5x while leaving the batch
    path's few ~20 ms sleeps untouched — so coarse-sleep the bulk and
    yield-spin the final stretch instead (``time.sleep(0)`` releases
    the GIL each probe)."""
    end = time.perf_counter_ns() + ns
    while True:
        rem = end - time.perf_counter_ns()
        if rem <= 0:
            return
        if rem > 1_500_000:
            time.sleep((rem - 1_200_000) / 1e9)
        else:
            time.sleep(0)


def percentile(samples, p):
    if not samples:
        return 0.0
    v = sorted(samples)
    idx = round((p / 100.0) * (len(v) - 1))
    return v[min(idx, len(v) - 1)]


class InjectedKill(Exception):
    """The deterministic replica-kill fault (mirrors the sim engine's
    injected panic)."""


# §L13 phase taxonomy (mirrors coordinator::trace::Phase). The first
# five are top-level: for one request they tile [t0, retirement] with
# no gaps or overlap, so per-request shares sum to 1.0 exactly. The
# rest are nested aggregates / events; the twin's per-request ledger
# only records the top-level five (like the Rust span ring), with
# prefill/decode-iteration/allreduce also kept as fleet aggregates.
PHASE_NAMES = [
    "admission-queue", "qos-queue", "router-dispatch", "prefill", "decode",
    "decode-iteration", "spec-draft", "spec-verify", "allreduce",
    "deploy-drain", "ladder-level",
]
TOP_PHASES = PHASE_NAMES[:5]


def new_tracer():
    """Collector handed to ``run_config(tracer=...)``: per-request
    timestamp marks (keyed by the reply queue's id), fleet-aggregate
    modeled phase ns, and ladder level transitions."""
    return {
        "req": {},
        "phase_ns": {"prefill": 0, "decode-iteration": 0},
        "ladder": [],
    }


def trace_attrs(tracer):
    """Per-request phase ledgers from the collector's marks (mirrors
    ``trace::per_request``). Missing marks telescope: a request shed at
    admission contributes only admission-queue time."""
    out = []
    for e in tracer["req"].values():
        popped = e.get("popped")
        if popped is None:
            continue
        released = e.get("released", popped)
        p0 = e.get("prefill0", released)
        p1 = e.get("prefill1", p0)
        # A request that never reached prefill has no decode span; its
        # ledger ends at the last recorded queue boundary, exactly like
        # the Rust span ring (a shed leaves only its queue spans).
        if "prefill1" in e:
            end = e.get("done", p1)
        elif "released" in e:
            end = e["released"]
        else:
            end = popped
        out.append({
            "tenant": e.get("tenant", 0),
            "e2e_s": max(end - e["t0"], 0.0),
            "phases": {
                "admission-queue": max(popped - e["t0"], 0.0),
                "qos-queue": max(released - popped, 0.0),
                "router-dispatch": max(p0 - released, 0.0),
                "prefill": max(p1 - p0, 0.0),
                "decode": max(end - p1, 0.0),
            },
        })
    return out


def trace_attribute(attrs, top_frac):
    """Summed phase ledger over the slowest ``top_frac`` of requests
    by e2e (mirrors ``trace::attribute``; 1.0 = every request)."""
    if not attrs:
        return {"requests": 0, "e2e_s": 0.0,
                "phases": {k: 0.0 for k in TOP_PHASES}}
    s = sorted(attrs, key=lambda a: -a["e2e_s"])
    frac = min(max(top_frac, 0.0), 1.0)
    take = max(1, min(len(s), int(len(s) * frac + 0.999999)))
    sel = s[:take]
    return {
        "requests": take,
        "e2e_s": sum(a["e2e_s"] for a in sel),
        "phases": {k: sum(a["phases"][k] for a in sel) for k in TOP_PHASES},
    }


def trace_shares(attr):
    """Top-level phase shares (mirrors ``Attribution::shares``): every
    phase name keyed, nested phases 0 in the per-request ledger."""
    total = sum(attr["phases"].values())
    sh = {k: 0.0 for k in PHASE_NAMES}
    if total <= 0:
        return sh
    for k, v in attr["phases"].items():
        sh[k] = round(v / total, 4)
    return sh


def trace_span_count(tracer):
    """Recorded interval count (mirrors ``TraceStats::span_count``:
    one span per closed top-level interval plus ladder events)."""
    n = len(tracer["ladder"])
    for e in tracer["req"].values():
        for k in ("popped", "released", "prefill0", "prefill1"):
            if k in e:
                n += 1
        if "done" in e and "prefill1" in e:
            n += 1
    return n


class Stats:
    def __init__(self):
        self.requests = 0
        self.batches = 0
        self.total_fill = 0
        self.prompt_tokens = 0
        self.executed_tokens = 0
        self.tokens_generated = 0
        self.tokens_saved = 0
        self.decode_steps = 0
        self.occupancy_sum = 0
        self.sheds = 0
        self.retries = 0
        self.restarts = 0
        self.failed = 0
        # §L12 execution-group telemetry: devices counts every worker
        # incarnation's group width (a whole-model replica is 1);
        # collectives/collective_ns count all-reduce rounds and their
        # modeled wire+latency time.
        self.devices = 0
        self.collectives = 0
        self.collective_ns = 0
        # §L8 SpecMeter mirror.
        self.drafted = 0
        self.accepted = 0
        self.draft_steps = 0
        self.verify_steps = 0
        self.spec_tokens = 0
        # §L9 PoolMeter mirror (capacity 0 = unpaged run).
        self.pool_capacity = 0
        self.pool_used_sum = 0
        self.pool_samples = 0
        self.pool_peak = 0
        self.prefix_hits = 0
        self.prefix_lookups = 0
        self.prefill_tokens_saved = 0
        self.evictions = 0
        self.alloc_stalls = 0
        self.latency_ms = []
        self.token_ms = []
        # §L10 TenantMeter mirror: tenant index -> outcome counters.
        self.tenant_meters = {}
        self.lock = threading.Lock()

    def tmeter(self, tenant):
        return self.tenant_meters.setdefault(tenant, {
            "requests": 0, "failed": 0, "sheds": 0, "slo_hits": 0,
            "tokens_generated": 0, "lat_ms": [],
        })

    def waste_ratio(self):
        if self.executed_tokens == 0:
            return 0.0
        return 1.0 - self.prompt_tokens / self.executed_tokens

    def mean_fill(self):
        return self.total_fill / self.batches if self.batches else 0.0

    def early_exit_ratio(self):
        budget = self.tokens_saved + self.tokens_generated
        return self.tokens_saved / budget if budget else 0.0

    def mean_occupancy(self):
        return self.occupancy_sum / self.decode_steps if self.decode_steps else 0.0

    def acceptance_rate(self):
        return self.accepted / self.drafted if self.drafted else 0.0

    def tokens_per_verify(self):
        return self.spec_tokens / self.verify_steps if self.verify_steps else 0.0

    def pool_utilization(self):
        if not self.pool_samples or not self.pool_capacity:
            return 0.0
        return self.pool_used_sum / self.pool_samples / self.pool_capacity

    def prefix_hit_rate(self):
        return self.prefix_hits / self.prefix_lookups if self.prefix_lookups else 0.0

    def note_response(self, latency_s, generated, saved, prompt,
                      tenant=0, slo_ms=0):
        self.latency_ms.append(latency_s * 1e3)
        self.token_ms.append(latency_s * 1e3 / max(generated, 1))
        self.tokens_generated += generated
        self.tokens_saved += saved
        self.prompt_tokens += prompt
        self.requests += 1
        m = self.tmeter(tenant)
        m["requests"] += 1
        m["tokens_generated"] += generated
        m["lat_ms"].append(latency_s * 1e3)
        # slo_ms 0 = no SLO: every completion counts as goodput
        # (TenantMeter::note_done).
        if slo_ms == 0 or latency_s * 1e3 <= slo_ms:
            m["slo_hits"] += 1

    def note_failure(self, tenant=0, shed=False):
        self.failed += 1
        m = self.tmeter(tenant)
        m["failed"] += 1
        if shed:
            self.sheds += 1
            m["sheds"] += 1


def run_config(workload, replicas, bucketed, continuous, slots=0, fault=None,
               dec_len=DEC_LEN, gamma=0, paged=None, trace_mode=False,
               tenants=None, autoscale=0, queue_cap=0, clients=0, tp=0,
               collective=None, sleepy=False, tracer=None):
    """One serving configuration. Request record (mirrors the Rust
    Admitted/ledger entry): (t0, admitted, reply, length, gen_len,
    attempts, row_hash, chunk_hashes, tenant, deadline). ``fault``
    mirrors FaultSpec: {"kill_replica": id, "kill_after_calls": n,
    "extra_kills": [(id, n), ...]} — a matching replica raises
    InjectedKill on that engine call; the router requeues its
    in-flight requests (bounded by MAX_RETRIES) and respawns a
    replacement (bounded by RESTARTS). ``gamma`` > 0 mirrors §L8
    speculative decoding on the continuous path (draft burst + fused
    verify per iteration, hash-sampled acceptance). ``paged`` mirrors
    SimPoolSpec: {"page_size": p, "pool_pages": n, "prefix_cache":
    bool} switches the continuous replicas onto the §L9 paged path
    (per-replica page pool, pool-aware admission, prefix reuse).

    §L12: ``tp`` >= 2 with a ``collective`` dict (CollectiveSpec-shaped:
    d_model/active_width/elem_bytes/link_gbps/latency_ns/syncs_per_step/
    partitioned_frac) turns each worker into a tp-way execution group —
    one thread standing in for tp lockstep shards, exactly like the
    Rust sim group: the partitioned share of per-token compute divides
    by tp (``CollectiveSpec::compute_scale``), every prefill/decode
    step pays ``syncs_per_step`` ring all-reduce rounds over the full
    static geometry (``step_collective_ns``: bytes = tokens *
    active_width * elem_bytes, time = latency * 2(tp-1) + bytes *
    (2(tp-1)/tp) / link), and a fault kill takes the whole group down
    atomically (the twin's worker IS the group). ``clients`` overrides
    the closed-loop client count (0 = the CLIENTS default).

    ``sleepy`` replaces the spin-precise ``nsleep`` on replica cost
    sleeps with a plain ``time.sleep``. Spin loops hold the GIL, so
    two replicas decoding concurrently serialize each other — which
    would erase the DP arm's real 2x-slot capacity advantage in the
    §L12 peak A/B. A plain sleep releases the GIL (true replica
    parallelism) at the price of per-step wakeup jitter; the
    saturated peak arms amortize that jitter, the latency-sensitive
    single-client light arms keep the spin (only one replica thread
    is ever hot there, so the GIL never bites).

    §L10: ``trace_mode`` treats ``workload`` as `load_trace` output and
    replays it open-loop (a feeder thread paces arrivals to the trace
    offsets — offered load comes from the trace, not from service
    capacity). ``tenants`` (QOS_TENANTS-shaped dicts) puts an
    `Admission` mirror in front of the router's bucket groups; SLOs
    become hard deadlines (stamped at admission, enforced at the
    router, the replica admit pass, and live slots — mirrors the Rust
    §L7 deadline machinery). ``autoscale`` is the ladder's replica
    budget; ``queue_cap`` the admission queue cap. Every request gets
    a terminal reply: True (tokens) or False (explicit failure)."""
    req_q = queue.Queue()
    # Bounded job queue = backpressure, mirroring the Rust router: every
    # ship is a try-put; a full queue parks the router briefly so the
    # supervision pass is never starved.
    job_q = queue.Queue(maxsize=max(replicas, 1))
    exit_q = queue.Queue()
    stats = Stats()
    if paged is not None and continuous:
        stats.pool_capacity = paged["pool_pages"]
    n_clients = clients if clients > 0 else (1 if trace_mode else CLIENTS)
    slots_n = slots if slots > 0 else BATCH_SIZE
    # §L12 execution-group cost model (SimSpec::sharded_leader +
    # ShardGroup::sync): partitioned per-token compute divides by tp,
    # dispatch/draft costs stay whole, and each engine step charges
    # syncs_per_step all-reduce rounds.
    group_tp = tp if tp >= 2 and collective is not None else 1
    cscale = 1.0
    if group_tp >= 2:
        pf = collective["partitioned_frac"]
        cscale = (1.0 - pf) + pf / group_tp
    t_ns = int(TOKEN_NS * cscale)
    dt_ns = int(DTOKEN_NS * cscale)

    def sync_ns(tokens, steps=1):
        """CollectiveSpec::step_collective_ns x steps, with the round
        counters accrued on the shared stats (the Rust group flushes
        the same totals at worker exit)."""
        if group_tp < 2:
            return 0
        hops = 2 * (group_tp - 1)
        byts = tokens * collective["active_width"] * collective["elem_bytes"]
        wire = byts * (hops / group_tp) / (collective["link_gbps"] * 1e9) * 1e9
        rounds = collective["syncs_per_step"] * steps
        ns = int(rounds * (collective["latency_ns"] * hops + wire))
        with stats.lock:
            stats.collectives += rounds
            stats.collective_ns += ns
        return ns

    def csleep(ns):
        # Replica cost sleep: spin-precise by default; GIL-releasing
        # plain sleep under ``sleepy`` (see the docstring above).
        if sleepy:
            time.sleep(ns / 1e9)
        else:
            nsleep(ns)
    state = {
        "live": set(range(max(replicas, 1))),
        "restarts_left": RESTARTS,
        "next_id": max(replicas, 1),
        "threads": [],
        "stops_sent": False,
    }

    kills = []
    if fault:
        kills = [(fault["kill_replica"], max(fault["kill_after_calls"], 1))]
        kills += [(r, max(c, 1)) for r, c in fault.get("extra_kills", [])]

    def make_bump(rid, calls_box):
        def bump():
            calls_box[0] += 1
            for kr, kc in kills:
                if kr == rid and calls_box[0] >= kc:
                    raise InjectedKill(
                        f"replica {rid} killed at engine call {calls_box[0]}"
                    )
        return bump

    def slo_of(t):
        return tenants[t]["slo_ms"] if tenants and t < len(tenants) else 0

    def tmark(req, key, t=None):
        # §L13: stamp one phase boundary on the request's trace entry
        # (entries are created at router pop; the GIL makes per-key
        # dict writes safe across the router/replica threads).
        if tracer is None:
            return
        e = tracer["req"].get(id(req[2]))
        if e is not None:
            e[key] = time.monotonic() if t is None else t

    def replica_batch(rid):
        # Run-to-completion decode_step loop: full-geometry prefill plus
        # every decode step for every row, early exit or not.
        calls = [0]
        bump = make_bump(rid, calls)
        with stats.lock:
            stats.devices += group_tp
        while True:
            job = job_q.get()
            if job is None:
                exit_q.put(("exit", rid, []))
                return
            bucket, group = job
            try:
                bump()
            except InjectedKill:
                exit_q.put(("crash", rid, [(bucket, r) for r in group]))
                return
            csleep(t_ns * BATCH_SIZE * bucket + dec_len * (
                DSTEP_NS + dt_ns * BATCH_SIZE
            ) + sync_ns(BATCH_SIZE * bucket) + sync_ns(BATCH_SIZE, dec_len))
            now = time.monotonic()
            with stats.lock:
                stats.batches += 1
                stats.total_fill += len(group)
                stats.executed_tokens += BATCH_SIZE * bucket
                for req in group:
                    stats.note_response(
                        now - req[0], req[4], 0, min(req[3], bucket),
                        req[8], slo_of(req[8]),
                    )
            for req in group:
                req[2].put(True)

    def replica_cont(rid):
        # Slot-based continuous batching, mirroring serve_continuous;
        # on an injected kill the in-flight ledger (pending + the group
        # mid-prefill + active slots) is reported back for requeue.
        calls = [0]
        bump = make_bump(rid, calls)
        with stats.lock:
            stats.devices += group_tp
        pending = deque()          # (bucket, req)
        active = [None] * slots_n  # [req, emitted, bucket]
        admitting = []             # (bucket, req) group mid-prefill
        router_gone = False
        # §L9: per-replica page pool + slot page tables + prefix cache,
        # mirroring PoolServing in serve_continuous.
        pool = cache = None
        tables = []
        if paged is not None:
            pool = PagePool(paged["page_size"], paged["pool_pages"])
            tables = [[] for _ in range(slots_n)]
            if paged["prefix_cache"]:
                cache = PrefixCache()

        def stash(job):
            bucket, group = job
            for req in group:
                pending.append((bucket, req))

        try:
            while True:
                n_live = sum(1 for a in active if a is not None)
                if not router_gone:
                    if n_live == 0 and not pending:
                        job = job_q.get()
                        if job is None:
                            router_gone = True
                        else:
                            stash(job)
                    while len(pending) < slots_n and not router_gone:
                        try:
                            job = job_q.get_nowait()
                        except queue.Empty:
                            break
                        if job is None:
                            router_gone = True
                        else:
                            stash(job)
                # §L9 release pass: retired slots hand their pages back
                # before admission sizes up the free pool.
                if pool is not None:
                    for s in range(slots_n):
                        if active[s] is None and tables[s]:
                            for page in tables[s]:
                                pool.release(page)
                            tables[s] = []
                # Admit same-bucket runs into free slots. On the paged
                # path each candidate is gated on its page footprint:
                # impossible requests shed, pressure evicts unpinned
                # cache pages LRU-first, a genuine shortage stalls
                # admission until live slots retire.
                free = deque(i for i, a in enumerate(active) if a is None)
                stalled = False
                while free and pending and not stalled:
                    bucket = pending[0][0]
                    admitting = []
                    ids = []
                    group_saved = 0
                    while (
                        pending
                        and pending[0][0] == bucket
                        and free
                        and len(admitting) < BATCH_SIZE
                    ):
                        req = pending[0][1]
                        # §L10 satellite: shed already-expired work at
                        # the front of the admit queue BEFORE any pool
                        # probes or page reservations are spent on it.
                        if req[9] is not None and time.monotonic() > req[9]:
                            pending.popleft()
                            with stats.lock:
                                stats.note_failure(req[8], shed=True)
                            tmark(req, "done")
                            req[2].put(False)
                            continue
                        if pool is None:
                            admitting.append(pending.popleft())
                            ids.append(free.popleft())
                            continue
                        total = pages_for(bucket + dec_len, pool.page_size)
                        if total > pool.capacity:
                            # PoolExhausted: could never fit, even with
                            # every page free — explicit terminal failure.
                            pending.popleft()
                            with stats.lock:
                                stats.note_failure(req[8])
                            tmark(req, "done")
                            req[2].put(False)
                            continue
                        chunks = req[7] if cache is not None else []
                        hits = cache.match_len(chunks) if cache is not None else 0
                        need = total - hits
                        while pool.free_pages() < need:
                            if cache is None or not cache.evict_lru(pool):
                                break
                            with stats.lock:
                                stats.evictions += 1
                        if pool.free_pages() < need:
                            with stats.lock:
                                stats.alloc_stalls += 1
                            stalled = True
                            break
                        pending.popleft()
                        sid = free.popleft()
                        table = tables[sid]
                        for k in range(hits):
                            page = cache.hit(chunks[k])
                            pool.retain(page)
                            table.append(page)
                        while len(table) < total:
                            table.append(pool.alloc())
                        with stats.lock:
                            stats.prefix_lookups += len(chunks)
                            stats.prefix_hits += hits
                        if cache is not None:
                            for k in range(hits, len(chunks)):
                                cache.insert(pool, chunks[k], table[k])
                        group_saved += hits * pool.page_size
                        admitting.append((bucket, req))
                        ids.append(sid)
                    if not admitting:
                        continue
                    bump()
                    pre_ns = (DSTEP_NS
                              + t_ns * (len(admitting) * bucket - group_saved)
                              + sync_ns(len(admitting) * bucket - group_saved))
                    pre0 = time.monotonic()
                    csleep(pre_ns)
                    if tracer is not None:
                        # §L13: router-dispatch closes / prefill opens at
                        # pre0 for every rider; the aggregate takes the
                        # modeled cost (the Rust breakdown's engine time).
                        pre1 = time.monotonic()
                        tracer["phase_ns"]["prefill"] += pre_ns
                        for _b, rq_ in admitting:
                            tmark(rq_, "prefill0", pre0)
                            tmark(rq_, "prefill1", pre1)
                    with stats.lock:
                        stats.batches += 1
                        stats.total_fill += len(admitting)
                        stats.executed_tokens += len(admitting) * bucket - group_saved
                        stats.prefill_tokens_saved += group_saved
                    for (b, req), sid in zip(admitting, ids):
                        active[sid] = [req, 0, b]
                    admitting = []
                # §L10: a slot whose deadline expired mid-decode retires
                # immediately as a shed instead of holding geometry to
                # emit tokens nobody will wait for.
                if tenants is not None:
                    now = time.monotonic()
                    for s, act in enumerate(active):
                        if act is None:
                            continue
                        req = act[0]
                        if req[9] is not None and now > req[9]:
                            active[s] = None
                            with stats.lock:
                                stats.note_failure(req[8], shed=True)
                            tmark(req, "done", now)
                            req[2].put(False)
                n_live = sum(1 for a in active if a is not None)
                if n_live == 0:
                    if router_gone and not pending:
                        exit_q.put(("exit", rid, []))
                        return
                    continue
                if pool is not None:
                    # Mirror of stats.pool.record: one occupancy sample
                    # per fused decode iteration.
                    used = pool.used_pages()
                    with stats.lock:
                        stats.pool_used_sum += used
                        stats.pool_samples += 1
                        stats.pool_peak = max(stats.pool_peak, used)
                if gamma > 0:
                    # §L8 draft/verify round: γ draft-model steps plus
                    # ONE fused full-model verify over the static slot
                    # geometry; each live slot advances by its
                    # hash-sampled accepted prefix + 1 correction
                    # token, truncated at EOS (gen_len) / dec_len
                    # exactly like plain decode.
                    bump()
                    csleep(gamma * (DRAFT_STEP_NS + DRAFT_TOKEN_NS * slots_n))
                    bump()
                    csleep(DSTEP_NS + dt_ns * slots_n + sync_ns(slots_n))
                    now = time.monotonic()
                    with stats.lock:
                        stats.decode_steps += 1
                        stats.occupancy_sum += n_live
                        stats.draft_steps += gamma
                        stats.verify_steps += 1
                    for s, act in enumerate(active):
                        if act is None:
                            continue
                        req, emitted, bucket = act[0], act[1], act[2]
                        a = sim_accept_len(req[6], emitted, gamma, ACCEPT_RATE)
                        cap = min(req[4], dec_len)  # EOS position
                        new_total = min(emitted + a + 1, cap)
                        act[1] = new_total
                        with stats.lock:
                            stats.drafted += gamma
                            stats.accepted += a
                            stats.spec_tokens += new_total - emitted
                        if new_total >= cap:
                            active[s] = None
                            with stats.lock:
                                stats.note_response(
                                    now - req[0], new_total, dec_len - new_total,
                                    min(req[3], bucket), req[8], slo_of(req[8]),
                                )
                            req[2].put(True)
                else:
                    # One fused decode iteration over the slot geometry.
                    bump()
                    it_ns = DSTEP_NS + dt_ns * slots_n + sync_ns(slots_n)
                    csleep(it_ns)
                    if tracer is not None:
                        tracer["phase_ns"]["decode-iteration"] += it_ns
                    now = time.monotonic()
                    with stats.lock:
                        stats.decode_steps += 1
                        stats.occupancy_sum += n_live
                    for s, act in enumerate(active):
                        if act is None:
                            continue
                        act[1] += 1
                        req, emitted, bucket = act[0], act[1], act[2]
                        if emitted >= req[4] or emitted >= dec_len:
                            active[s] = None
                            with stats.lock:
                                stats.note_response(
                                    now - req[0], emitted, dec_len - emitted,
                                    min(req[3], bucket), req[8], slo_of(req[8]),
                                )
                            tmark(req, "done", now)
                            req[2].put(True)
        except InjectedKill:
            unfinished = list(pending) + list(admitting)
            unfinished += [(act[2], act[0]) for act in active if act is not None]
            exit_q.put(("crash", rid, unfinished))

    target = replica_cont if continuous else replica_batch

    def handle_exit(ev, groups):
        kind, rid, unfinished = ev
        state["live"].discard(rid)
        if kind == "exit":
            return
        # Crash: requeue in-flight requests (bounded retries) unless the
        # drain already closed the job queue, then respawn within budget.
        for bucket, req in unfinished:
            attempts = req[5] + 1
            if state["stops_sent"] or attempts > MAX_RETRIES:
                with stats.lock:
                    stats.note_failure(req[8])
                req[2].put(False)
            else:
                with stats.lock:
                    stats.retries += 1
                groups.setdefault(bucket, []).append(
                    (req[0], time.monotonic(), req[2], req[3], req[4], attempts,
                     req[6], req[7], req[8], req[9])
                )
        if not state["stops_sent"] and state["restarts_left"] > 0:
            state["restarts_left"] -= 1
            with stats.lock:
                stats.restarts += 1
            nid = state["next_id"]
            state["next_id"] += 1
            state["live"].add(nid)
            t = threading.Thread(target=target, args=(nid,), name=f"replica-{nid}")
            state["threads"].append(t)
            t.start()

    def router():
        # bucket -> list of request records; latency is reported from
        # the client-side t0, the batch-window deadline runs from
        # admission (mirrors the Rust router/supervisor).
        groups = {}
        live_clients = n_clients
        disconnected = False
        # §L10: admission front-end + the ladder's replica budget.
        qos = Admission(tenants, queue_cap, time.monotonic()) if tenants else None
        autoscale_left = [autoscale]
        qos_level = [0]  # §L13: last observed ladder level
        while True:
            # Supervision pass.
            while True:
                try:
                    ev = exit_q.get_nowait()
                except queue.Empty:
                    break
                handle_exit(ev, groups)
            dead = not state["live"] and state["restarts_left"] == 0
            if dead:
                for bucket in list(groups):
                    for req in groups.pop(bucket):
                        with stats.lock:
                            stats.note_failure(req[8])
                        req[2].put(False)
                # Parked admission records have no fleet left either.
                if qos is not None:
                    for rec in qos.release(qos.queued):
                        with stats.lock:
                            stats.note_failure(rec[8])
                        rec[2].put(False)
                # Strand recovery: jobs already queued when the last
                # replica died have no consumer left — fail them
                # explicitly instead of leaving their clients blocked.
                while True:
                    try:
                        job = job_q.get_nowait()
                    except queue.Empty:
                        break
                    if job is None:
                        continue
                    for req in job[1]:
                        with stats.lock:
                            stats.note_failure(req[8])
                        req[2].put(False)
                if disconnected:
                    return
            # §L10 QoS pass (mirrors Router::route): expire parked and
            # grouped work, tick the overload ladder and execute its
            # actions, then release by weighted priority into groups.
            if qos is not None and not dead:
                nowq = time.monotonic()
                for rec in qos.take_expired(nowq):
                    with stats.lock:
                        stats.note_failure(rec[8], shed=True)
                    tmark(rec, "done", nowq)
                    rec[2].put(False)
                for bucket in list(groups):
                    kept = []
                    for req in groups[bucket]:
                        if req[9] is not None and nowq > req[9]:
                            with stats.lock:
                                stats.note_failure(req[8], shed=True)
                            tmark(req, "done", nowq)
                            req[2].put(False)
                        else:
                            kept.append(req)
                    if kept:
                        groups[bucket] = kept
                    else:
                        del groups[bucket]
                downstream = sum(len(g) for g in groups.values())
                hint = max(len(state["live"]), 1) * BATCH_SIZE
                for action in qos.tick(nowq, downstream, hint):
                    if (
                        action == "scale_up"
                        and autoscale_left[0] > 0
                        and not state["stops_sent"]
                    ):
                        autoscale_left[0] -= 1
                        nid = state["next_id"]
                        state["next_id"] += 1
                        state["live"].add(nid)
                        t = threading.Thread(
                            target=target, args=(nid,), name=f"replica-{nid}"
                        )
                        state["threads"].append(t)
                        t.start()
                    # scale_down is a no-op here: ladder replicas simply
                    # exit at drain (the Rust router parks one with a
                    # SCALE_DOWN sentinel job instead).
                if tracer is not None and qos.level != qos_level[0]:
                    # §L13: one event per ladder transition (the Rust
                    # router records a LadderLevel span per ±1 step).
                    tracer["ladder"].append((time.monotonic(), qos.level))
                    qos_level[0] = qos.level
                room = max(len(state["live"]) * BATCH_SIZE * 2 - downstream, 0)
                if disconnected:
                    room = qos.queued  # drain: flush everything parked
                for rec in qos.release(room):
                    rec = rec[:1] + (time.monotonic(),) + rec[2:]
                    tmark(rec, "released", rec[1])
                    bucket = bucket_for(rec[3], ENC_LEN) if bucketed else ENC_LEN
                    groups.setdefault(bucket, []).append(rec)
            # Flush pass (mirrors the Rust router): every ship is a
            # try-put, but full groups ship first — fullest bucket
            # first, chunked to batch size — and while a full group
            # cannot ship, admission pauses below (the pre-L7 blocking
            # send's backpressure) and due partials wait their turn.
            now = time.monotonic()
            full_unsent = False
            due_unsent = False
            order = [] if dead else sorted(groups, key=lambda b: -len(groups[b]))
            for bucket in order:
                if len(groups[bucket]) < BATCH_SIZE and not disconnected:
                    continue
                g = groups.pop(bucket)
                while g:
                    chunk, g = g[:BATCH_SIZE], g[BATCH_SIZE:]
                    try:
                        job_q.put_nowait((bucket, chunk))
                    except queue.Full:
                        groups[bucket] = chunk + g
                        full_unsent = True
                        break
                if full_unsent:
                    break
            if not full_unsent and not dead:
                for bucket in list(groups.keys()):
                    group = groups[bucket]
                    if now < group[0][1] + WINDOW_S:
                        continue
                    g = groups.pop(bucket)
                    try:
                        job_q.put_nowait((bucket, g))
                    except queue.Full:
                        groups[bucket] = g
                        due_unsent = True
                        break
            # Drain: stop admissions, flush, close the queue, collect
            # replica exits.
            if disconnected:
                if not groups and (qos is None or qos.queued == 0) \
                        and not state["stops_sent"]:
                    for _ in range(len(state["live"])):
                        job_q.put(None)
                    state["stops_sent"] = True
                if state["stops_sent"] and not state["live"]:
                    return
                try:
                    handle_exit(exit_q.get(timeout=0.05), groups)
                except queue.Empty:
                    pass
                continue
            # Admit pass, capped at the supervision tick. While a full
            # group waits for queue capacity, admission pauses (no
            # req_q drain) so clients feel the backpressure.
            msg = None
            if full_unsent or due_unsent:
                wait = max(WINDOW_S, 0.0002)
            elif not groups:
                wait = 0.025
            else:
                oldest = min(g[0][1] for g in groups.values())
                wait = oldest + WINDOW_S - time.monotonic()
            if full_unsent:
                time.sleep(min(wait, 0.025))
            elif wait > 0:
                try:
                    m = req_q.get(timeout=min(wait, 0.025))
                    if m is None:
                        live_clients -= 1
                        if live_clients == 0:
                            disconnected = True
                    else:
                        msg = m
                except queue.Empty:
                    pass
            if msg is not None:
                t0, reply, length, gen_len, h, chunks, tenant = msg
                rec = (t0, time.monotonic(), reply, length, gen_len, 0, h,
                       chunks, tenant, None)
                if tracer is not None:
                    # §L13: admission-queue closes at the router pop;
                    # without a QoS front-end the release is the pop.
                    e = {"t0": t0, "popped": rec[1], "tenant": tenant}
                    if qos is None:
                        e["released"] = rec[1]
                    tracer["req"][id(reply)] = e
                if qos is None:
                    bucket = bucket_for(length, ENC_LEN) if bucketed else ENC_LEN
                    groups.setdefault(bucket, []).append(rec)
                else:
                    verdict, out = qos.offer(
                        rec, time.monotonic(),
                        sum(len(g) for g in groups.values()),
                    )
                    if verdict == "shed":
                        with stats.lock:
                            stats.note_failure(out[8], shed=True)
                        tmark(out, "done")
                        out[2].put(False)

    def client(c):
        for length, h, chunks in workload[c::n_clients]:
            reply = queue.SimpleQueue()
            # gen_len derives from the row hash at THIS run's dec_len,
            # mirroring the sim engine's per-run EOS sampling.
            req_q.put(
                (time.monotonic(), reply, length, sim_gen_len(h, dec_len), h,
                 chunks, 0)
            )
            reply.get()  # terminal: True (tokens) or False (failure)
        req_q.put(None)  # this client is done

    def feeder():
        # §L10 open-loop trace replay: arrivals are paced by the trace,
        # not by service completions, so overload genuinely builds queue
        # depth instead of self-throttling like the closed-loop clients.
        replies = []
        start = time.monotonic()
        for at_us, tenant, length, h, chunks in workload:
            delay = start + at_us / 1e6 - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            reply = queue.SimpleQueue()
            replies.append(reply)
            req_q.put(
                (time.monotonic(), reply, length, sim_gen_len(h, dec_len), h,
                 chunks, tenant)
            )
        req_q.put(None)
        for reply in replies:
            reply.get()  # every trace request still gets a terminal

    router_thread = threading.Thread(target=router, name="router")
    state["threads"] = [
        threading.Thread(target=target, args=(i,), name=f"replica-{i}")
        for i in range(max(replicas, 1))
    ]
    t_start = time.monotonic()
    if trace_mode:
        client_threads = [threading.Thread(target=feeder, name="feeder")]
    else:
        client_threads = [
            threading.Thread(target=client, args=(c,), name=f"client-{c}")
            for c in range(n_clients)
        ]
    for t in [router_thread] + state["threads"] + client_threads:
        t.start()
    for t in client_threads:
        t.join()
    router_thread.join()
    for t in state["threads"]:
        t.join()
    wall = time.monotonic() - t_start
    qps = len(workload) / max(wall, 1e-9)
    # §L7 terminal accounting: every submitted request resolved, with
    # tokens or an explicit failure — none dropped or hung.
    assert stats.requests + stats.failed == len(workload), (
        stats.requests, stats.failed, len(workload),
    )
    if fault is None and tenants is None:
        assert stats.failed == 0, stats.failed
    # §L10: per-tenant meters partition the global counters exactly.
    if tenants is not None:
        per = sum(
            m["requests"] + m["failed"] for m in stats.tenant_meters.values()
        )
        assert per == stats.requests + stats.failed, (per, stats.requests)
    return qps, stats


def probe_prompts(count, enc_len):
    """Pinned canary probe set, bit-for-bit `deploy::probe_prompts`."""
    out = []
    for k in range(count):
        ln = min(max(enc_len // 2 + k + 1, 1), max(enc_len, 1))
        out.append([2 + ((i * 7 + k * 131) % 89) for i in range(ln)])
    return out


def sim_token(h, j, vocab):
    """Decode token at position j (coordinator::server::sim_token)."""
    x = ((h * (j + 1)) + 0x9E3779B97F4A7C15) & MASK
    x ^= x >> 29
    return 2 + (x % (max(vocab, 3) - 2))


def sim_row_tokens(h, dec_len, salt):
    """EOS-truncated decode row for a weight version: EOS position and
    generation length key off the UNSALTED hash (a wrong-token version
    is cost-identical to the old one — only the §L11 parity probe can
    tell them apart), token values off the salted one."""
    g = sim_gen_len(h, dec_len)
    return [1 if j + 1 == g else sim_token((h ^ salt) & MASK, j, VOCAB)
            for j in range(g)]


def probe_rows(salt):
    """What a version answers on the pinned probes — the canary gate's
    token-parity fingerprint."""
    return [
        sim_row_tokens(sim_row_hash(p), DEC_LEN, salt)
        for p in probe_prompts(min(SWAP_PROBES, BATCH_SIZE), ENC_LEN)
    ]


def swap_status_str(status):
    """DeployStatus Display mirror (the JSON stores these strings)."""
    s, n, r = status["state"], status["swapped"], status["reason"]
    if s == "idle":
        return "idle"
    if s == "in_progress":
        return f"rolling out v1: {n}/2 replicas swapped"
    if s == "completed":
        return f"completed: {n} replica(s) on v1"
    if s == "rolled_back":
        return f"rolled back v1 after {n} swap(s): {r}"
    return f"rollout of v1 aborted: {r}"


def run_swap_trace(trace, swap_salt=None, fault=None):
    """One §L11 arm: the burst trace replayed open-loop through a paged
    cont x2 fleet (no QoS — every request runs to completion), with a
    rollout to a version of ``swap_salt`` (None = no rollout, 0 =
    healthy successor at SWAP_COST_MULT cost, BAD_VERSION_SALT =
    wrong-token successor) fired once the wall clock passes 25% of the
    trace span. Mirrors the bench's drive_trace_swap: the run does not
    shut down until the rollout reaches a terminal verdict, wall stops
    at the last response (a post-trace probation must not deflate
    qps), and the response-token hash folds every reply in submission
    order. Returns (qps, stats, deploy, status, token_hash)."""
    span_s = max(trace[-1][0] / 1e6, 1e-9)
    swap_at = span_s * 0.25
    replicas, slots_n = 2, BATCH_SIZE
    versions = {0: {"salt": 0, "mult": 1.0}}
    if swap_salt is not None:
        versions[1] = {"salt": swap_salt & MASK, "mult": SWAP_COST_MULT}

    req_q = queue.Queue()
    job_q = queue.Queue(maxsize=replicas)
    exit_q = queue.Queue()
    deploy_q = queue.Queue()       # ("probe", rid, rows) from canaries
    stats = Stats()
    stats.pool_capacity = SWAP_POOL_PAGES
    state = {
        "live": set(range(replicas)),
        "version": {r: 0 for r in range(replicas)},
        "decided": 0,              # crash respawns land on this version
        "restarts_left": RESTARTS,
        "next_id": replicas,
        "threads": [],
        "stops_sent": False,
    }
    # DeployShared mirror: a drain lever scoped to one replica, and the
    # canary's admission gate (verdict set by the router, Event wakes
    # the held canary).
    drain_ev = {}
    gates = {}
    deploy = {"canary_pass": 0, "canary_fail": 0, "rollbacks": 0,
              "completed": 0, "aborted": 0,
              "versions": {0: {"requests": 0, "failed": 0, "sheds": 0,
                               "lat_ms": []}}}
    status = {"state": "idle", "swapped": 0, "reason": ""}

    kills = []
    if fault:
        kills = [(fault["kill_replica"], max(fault["kill_after_calls"], 1))]

    def vmeter(v):
        return deploy["versions"].setdefault(
            v, {"requests": 0, "failed": 0, "sheds": 0, "lat_ms": []})

    def note_ok(v, latency_s, generated, saved, prompt):
        with stats.lock:
            stats.note_response(latency_s, generated, saved, prompt)
            m = vmeter(v)
            m["requests"] += 1
            m["lat_ms"].append(latency_s * 1e3)

    def note_fail(v):
        with stats.lock:
            stats.note_failure()
            vmeter(v)["failed"] += 1

    def replica(rid, version, canary=False):
        vs = versions[version]
        t_ns = int(TOKEN_NS * vs["mult"])
        dt_ns = int(DTOKEN_NS * vs["mult"])
        ds_ns = int(DSTEP_NS * vs["mult"])
        calls = [0]

        def bump():
            calls[0] += 1
            for kr, kc in kills:
                if kr == rid and calls[0] >= kc:
                    raise InjectedKill(f"replica {rid} @ call {calls[0]}")

        if canary:
            # Canary gate (deploy::canary_gate): decode the pinned
            # probes BEFORE pulling any live traffic, publish the rows,
            # hold for the router's verdict. An abandoned canary exits
            # having served exactly zero client requests.
            rows = probe_rows(vs["salt"])
            for p in probe_prompts(min(SWAP_PROBES, BATCH_SIZE), ENC_LEN):
                g = sim_gen_len(sim_row_hash(p), DEC_LEN)
                nsleep(ds_ns + t_ns * len(p) + g * (ds_ns + dt_ns))
            deploy_q.put(("probe", rid, rows))
            gate = gates[rid]
            gate["event"].wait(SWAP_HOLD_S)
            if gate["verdict"] != "admit":
                exit_q.put(("exit", rid, []))
                return

        pending = deque()
        active = [None] * slots_n
        admitting = []
        router_gone = False
        retiring = False
        pool = PagePool(PAGE_SIZE, SWAP_POOL_PAGES)
        tables = [[] for _ in range(slots_n)]

        def stash(job):
            bucket, group = job
            for req in group:
                pending.append((bucket, req))

        try:
            while True:
                # take_drain: once the lever targets us, stop pulling
                # new work; in-flight slots run to completion and
                # untouched pending hands back to the router.
                if not retiring and drain_ev.get(rid) is not None \
                        and drain_ev[rid].is_set():
                    retiring = True
                n_live = sum(1 for a in active if a is not None)
                if not router_gone and not retiring:
                    if n_live == 0 and not pending:
                        try:
                            job = job_q.get(timeout=0.025)
                        except queue.Empty:
                            job = ()   # idle tick: re-check the lever
                        if job is None:
                            router_gone = True
                        elif job:
                            stash(job)
                    while len(pending) < slots_n and not router_gone:
                        try:
                            job = job_q.get_nowait()
                        except queue.Empty:
                            break
                        if job is None:
                            router_gone = True
                        else:
                            stash(job)
                for s in range(slots_n):
                    if active[s] is None and tables[s]:
                        for page in tables[s]:
                            pool.release(page)
                        tables[s] = []
                free = deque(i for i, a in enumerate(active) if a is None)
                stalled = False
                while free and pending and not stalled and not retiring:
                    bucket = pending[0][0]
                    admitting = []
                    ids = []
                    while (pending and pending[0][0] == bucket and free
                           and len(admitting) < BATCH_SIZE):
                        req = pending[0][1]
                        total = pages_for(bucket + DEC_LEN, pool.page_size)
                        if total > pool.capacity:
                            pending.popleft()
                            note_fail(version)
                            req[2].put(("fail",))
                            continue
                        if pool.free_pages() < total:
                            with stats.lock:
                                stats.alloc_stalls += 1
                            stalled = True
                            break
                        pending.popleft()
                        sid = free.popleft()
                        while len(tables[sid]) < total:
                            tables[sid].append(pool.alloc())
                        admitting.append((bucket, req))
                        ids.append(sid)
                    if not admitting:
                        continue
                    bump()
                    nsleep(ds_ns + t_ns * len(admitting) * bucket)
                    with stats.lock:
                        stats.batches += 1
                        stats.total_fill += len(admitting)
                        stats.executed_tokens += len(admitting) * bucket
                    for (b, req), sid in zip(admitting, ids):
                        active[sid] = [req, 0, b]
                    admitting = []
                n_live = sum(1 for a in active if a is not None)
                if n_live == 0:
                    if retiring:
                        exit_q.put(("drained", rid, list(pending)))
                        return
                    if router_gone and not pending:
                        exit_q.put(("exit", rid, []))
                        return
                    continue
                used = pool.used_pages()
                with stats.lock:
                    stats.pool_used_sum += used
                    stats.pool_samples += 1
                    stats.pool_peak = max(stats.pool_peak, used)
                bump()
                nsleep(ds_ns + dt_ns * slots_n)
                now = time.monotonic()
                with stats.lock:
                    stats.decode_steps += 1
                    stats.occupancy_sum += n_live
                for s, act in enumerate(active):
                    if act is None:
                        continue
                    act[1] += 1
                    req, emitted, bucket = act[0], act[1], act[2]
                    if emitted >= req[4] or emitted >= DEC_LEN:
                        active[s] = None
                        note_ok(version, now - req[0], emitted,
                                DEC_LEN - emitted, min(req[3], bucket))
                        req[2].put(("ok", version))
        except InjectedKill:
            unfinished = list(pending) + list(admitting)
            unfinished += [(a[2], a[0]) for a in active if a is not None]
            exit_q.put(("crash", rid, unfinished))

    def spawn(version, canary=False):
        rid = state["next_id"]
        state["next_id"] += 1
        state["live"].add(rid)
        state["version"][rid] = version
        if canary:
            gates[rid] = {"event": threading.Event(), "verdict": None}
        t = threading.Thread(target=replica, args=(rid, version, canary),
                             name=f"replica-{rid}")
        state["threads"].append(t)
        t.start()
        return rid

    # Rollout driver state, owned by the router thread.
    ro = {"phase": None, "canary": None, "target": None, "baseline": None,
          "admit_t": 0.0, "admit_req": 0, "admit_fail": 0,
          "fleet_p95": 0.0, "v0_seen": 0, "started": False}

    def old_target():
        olds = [r for r in state["live"]
                if state["version"][r] != 1 and r != ro["canary"]]
        return min(olds) if olds else None

    def abandon_canary():
        # A failing canary is drained out (it may be mid-decode during
        # probation); its untouched pending requeues like any drain.
        cid = ro["canary"]
        if cid is not None:
            drain_ev[cid] = threading.Event()
            drain_ev[cid].set()
        ro["canary"] = None

    def rollback(reason):
        deploy["canary_fail"] += 1
        deploy["rollbacks"] += 1
        status.update(state="rolled_back", reason=reason)
        ro["phase"] = None
        ro["canary"] = None
        state["decided"] = 0
        spawn(0)  # the drained slot reloads the old version

    def promote():
        deploy["canary_pass"] += 1
        status["swapped"] += 1
        state["decided"] = 1
        ro["canary"] = None
        nxt = old_target()
        if nxt is None:
            status.update(state="completed")
            deploy["completed"] += 1
            ro["phase"] = None
        else:
            ro["phase"] = "draining"
            ro["target"] = nxt
            drain_ev[nxt] = threading.Event()
            drain_ev[nxt].set()

    def rollout_tick():
        if ro["phase"] is None:
            return
        # Fleet p95 EWMA from old-version completions (0.8/0.2), the
        # yardstick the canary's probation latency is judged against.
        with stats.lock:
            v0 = deploy["versions"][0]["lat_ms"]
            if len(v0) > ro["v0_seen"]:
                ro["v0_seen"] = len(v0)
                p = percentile(v0, 95)
                ro["fleet_p95"] = p if ro["fleet_p95"] == 0 \
                    else 0.8 * ro["fleet_p95"] + 0.2 * p
        if ro["phase"] != "probation" or ro["canary"] is None:
            return
        now = time.monotonic()
        with stats.lock:
            m = vmeter(1)
            served = m["requests"] - ro["admit_req"]
            failed = (m["failed"] - m["sheds"]) - ro["admit_fail"]
            lat = list(m["lat_ms"][ro["admit_req"]:])
        if served + failed < SWAP_PROBATION \
                and now - ro["admit_t"] < SWAP_PROBATION_S:
            return
        err = failed / max(served + failed, 1)
        if err > SWAP_MAX_ERR:
            abandon_canary()
            rollback(f"canary error rate {err:.2f} over {SWAP_MAX_ERR}")
        elif ro["fleet_p95"] > 0 and lat \
                and percentile(lat, 95) > ro["fleet_p95"] * SWAP_LAT_FACTOR:
            abandon_canary()
            rollback("canary p95 blew the fleet latency gate")
        else:
            promote()

    def handle_exit(ev, groups):
        kind, rid, unfinished = ev
        state["live"].discard(rid)
        was_canary = rid == ro["canary"]
        if kind == "drained" or (kind == "crash" and rid == ro["target"]
                                 and ro["phase"] == "draining"):
            # Old replica gone (drained clean, or crashed mid-drain):
            # requeue its leftovers untouched — a drain spends neither
            # retry nor restart budget — and bring up the canary.
            for bucket, req in unfinished:
                groups.setdefault(bucket, []).append(req)
            if ro["phase"] == "draining" and rid == ro["target"]:
                ro["target"] = None
                ro["phase"] = "probing"
                ro["canary"] = spawn(1, canary=True)
            return
        if kind == "exit":
            if was_canary and ro["phase"] in ("probing", "probation"):
                # Gate hold expired without a verdict.
                rollback("canary abandoned at the gate")
            return
        # Crash: requeue in-flight (bounded retries). Canary crashes
        # roll back WITHOUT spending §L7 restart budget; fleet crashes
        # respawn on the DECIDED version within budget.
        for bucket, req in unfinished:
            attempts = req[5] + 1
            if state["stops_sent"] or attempts > MAX_RETRIES:
                note_fail(state["version"].get(rid, 0))
                req[2].put(("fail",))
            else:
                with stats.lock:
                    stats.retries += 1
                groups.setdefault(bucket, []).append(
                    (req[0], time.monotonic(), req[2], req[3], req[4],
                     attempts, req[6], req[7], req[8], req[9]))
        if was_canary:
            rollback("canary crashed before completing probation")
            return
        if not state["stops_sent"] and state["restarts_left"] > 0:
            state["restarts_left"] -= 1
            with stats.lock:
                stats.restarts += 1
            spawn(state["decided"])

    def router():
        groups = {}
        disconnected = False
        start = time.monotonic()
        while True:
            while True:
                try:
                    ev = exit_q.get_nowait()
                except queue.Empty:
                    break
                handle_exit(ev, groups)
            # RolloutDriver::tick — fire, judge probes, gate probation.
            if swap_salt is not None and not ro["started"] \
                    and not disconnected \
                    and time.monotonic() - start >= swap_at:
                ro["started"] = True
                ro["baseline"] = probe_rows(0)
                vmeter(1)  # ledger row exists even if v1 never serves
                status.update(state="in_progress")
                tgt = old_target()
                ro["phase"] = "draining"
                ro["target"] = tgt
                drain_ev[tgt] = threading.Event()
                drain_ev[tgt].set()
            while True:
                try:
                    what, cid, rows = deploy_q.get_nowait()
                except queue.Empty:
                    break
                if what == "probe" and cid == ro["canary"]:
                    gate = gates[cid]
                    if rows == ro["baseline"]:
                        gate["verdict"] = "admit"
                        ro["phase"] = "probation"
                        ro["admit_t"] = time.monotonic()
                        with stats.lock:
                            m = vmeter(1)
                            ro["admit_req"] = m["requests"]
                            ro["admit_fail"] = m["failed"] - m["sheds"]
                    else:
                        gate["verdict"] = "abandon"
                        rollback("canary failed the token-parity probe")
                    gate["event"].set()
            rollout_tick()
            if disconnected and ro["phase"] is not None:
                # shutdown() mid-rollout: clean abort, then the full
                # §L7 drain below still resolves every request.
                abandon_canary()
                deploy["aborted"] += 1
                status.update(state="aborted", reason="server shut down")
                ro["phase"] = None
            dead = not state["live"] and state["restarts_left"] == 0
            if dead:
                for bucket in list(groups):
                    for req in groups.pop(bucket):
                        note_fail(0)
                        req[2].put(("fail",))
                while True:
                    try:
                        job = job_q.get_nowait()
                    except queue.Empty:
                        break
                    if job is not None:
                        for req in job[1]:
                            note_fail(0)
                            req[2].put(("fail",))
                if disconnected:
                    return
            now = time.monotonic()
            full_unsent = False
            due_unsent = False
            order = [] if dead else sorted(groups, key=lambda b: -len(groups[b]))
            for bucket in order:
                if len(groups[bucket]) < BATCH_SIZE and not disconnected:
                    continue
                g = groups.pop(bucket)
                while g:
                    chunk, g = g[:BATCH_SIZE], g[BATCH_SIZE:]
                    try:
                        job_q.put_nowait((bucket, chunk))
                    except queue.Full:
                        groups[bucket] = chunk + g
                        full_unsent = True
                        break
                if full_unsent:
                    break
            if not full_unsent and not dead and not disconnected:
                for bucket in list(groups.keys()):
                    group = groups[bucket]
                    if now < group[0][1] + WINDOW_S:
                        continue
                    g = groups.pop(bucket)
                    try:
                        job_q.put_nowait((bucket, g))
                    except queue.Full:
                        groups[bucket] = g
                        due_unsent = True
                        break
            if disconnected:
                if not groups and not state["stops_sent"] \
                        and ro["phase"] is None:
                    for _ in range(len(state["live"])):
                        job_q.put(None)
                    state["stops_sent"] = True
                if state["stops_sent"] and not state["live"]:
                    return
                try:
                    handle_exit(exit_q.get(timeout=0.05), groups)
                except queue.Empty:
                    pass
                continue
            msg = None
            if full_unsent or due_unsent:
                wait = max(WINDOW_S, 0.0002)
            elif not groups:
                wait = 0.025
            else:
                oldest = min(g[0][1] for g in groups.values())
                wait = oldest + WINDOW_S - time.monotonic()
            if full_unsent:
                time.sleep(min(wait, 0.025))
            elif wait > 0:
                try:
                    m = req_q.get(timeout=min(wait, 0.025))
                    if m is None:
                        disconnected = True
                    else:
                        msg = m
                except queue.Empty:
                    pass
            if msg is not None:
                t0, reply, length, gen_len, h, chunks, tenant = msg
                rec = (t0, time.monotonic(), reply, length, gen_len, 0, h,
                       chunks, tenant, None)
                groups.setdefault(bucket_for(length, ENC_LEN), []).append(rec)

    replies = []

    def feeder():
        start = time.monotonic()
        for at_us, tenant, length, h, chunks in trace:
            delay = start + at_us / 1e6 - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            reply = queue.SimpleQueue()
            replies.append((reply, h))
            req_q.put((time.monotonic(), reply, length,
                       sim_gen_len(h, DEC_LEN), h, chunks, tenant))

    router_thread = threading.Thread(target=router, name="router")
    state["threads"] = [
        threading.Thread(target=replica, args=(i, 0), name=f"replica-{i}")
        for i in range(replicas)
    ]
    feed = threading.Thread(target=feeder, name="feeder")
    t_start = time.monotonic()
    for t in [router_thread] + state["threads"] + [feed]:
        t.start()
    feed.join()
    # Response-token parity fingerprint, folded in submission order
    # exactly like the bench (FNV over each row, then the row length
    # mixed in; a failed request contributes nothing either way).
    salts = {v: versions[v]["salt"] for v in versions}
    token_hash = 0xCBF29CE484222325
    for reply, h in replies:
        out = reply.get()
        if out[0] != "ok":
            continue
        toks = sim_row_tokens(h, DEC_LEN, salts[out[1]])
        for t in toks:
            token_hash = ((token_hash ^ t) * 0x00000100000001B3) & MASK
        token_hash ^= (len(toks) << 17) & MASK
    wall = time.monotonic() - t_start
    # The rollout must reach a terminal verdict before the drain (the
    # bench polls deploy_status the same way) — the swap outcome is
    # part of the measurement, never racing shutdown.
    if swap_salt is not None:
        deadline = time.monotonic() + 120
        while status["state"] in ("idle", "in_progress"):
            assert time.monotonic() < deadline, "rollout wedged"
            time.sleep(0.01)
    req_q.put(None)
    router_thread.join()
    for t in state["threads"]:
        t.join()
    qps = len(trace) / max(wall, 1e-9)
    # Terminal accounting + the per-version ledger partition invariant
    # (ensure!d on every run in the bench).
    assert stats.requests + stats.failed == len(trace), (
        stats.requests, stats.failed, len(trace))
    vr = sum(m["requests"] for m in deploy["versions"].values())
    vf = sum(m["failed"] for m in deploy["versions"].values())
    assert vr == stats.requests and vf == stats.failed, (
        vr, vf, stats.requests, stats.failed)
    return qps, stats, deploy, dict(status), token_hash


def row(mode, replicas, qps, stats):
    r = {
        "mode": mode,
        "replicas": replicas,
        "qps": round(qps, 1),
        "mean_fill": round(stats.mean_fill(), 3),
        "waste_ratio": round(stats.waste_ratio(), 4),
        "prompt_tokens": stats.prompt_tokens,
        "executed_tokens": stats.executed_tokens,
        "batches": stats.batches,
        "tokens_generated": stats.tokens_generated,
        "early_exit_saved_ratio": round(stats.early_exit_ratio(), 4),
        "decode_steps": stats.decode_steps,
        "mean_occupancy": round(stats.mean_occupancy(), 3),
        "token_ms": round(
            sum(stats.token_ms) / len(stats.token_ms) if stats.token_ms else 0.0, 3
        ),
        "p50_ms": round(percentile(stats.latency_ms, 50), 2),
        "p95_ms": round(percentile(stats.latency_ms, 95), 2),
        "p99_ms": round(percentile(stats.latency_ms, 99), 2),
        "devices": stats.devices,
    }
    if stats.collectives:
        r.update({
            "collectives": stats.collectives,
            "collective_ns": stats.collective_ns,
            "mean_allreduce_ns": round(stats.collective_ns / stats.collectives, 1),
        })
    if stats.pool_capacity:
        r.update({
            "pool_capacity": stats.pool_capacity,
            "pool_occupancy": round(stats.pool_utilization(), 4),
            "pool_peak": stats.pool_peak,
            "prefix_hit_rate": round(stats.prefix_hit_rate(), 4),
            "prefill_tokens_saved": stats.prefill_tokens_saved,
            "prefix_evictions": stats.evictions,
            "alloc_stalls": stats.alloc_stalls,
        })
    return r


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_server_throughput.json"
    workload = mixed_prompts(REQUESTS, ENC_LEN, VOCAB, 0x5E0A11)

    base_qps, base_stats = run_config(workload, 1, bucketed=False, continuous=False)
    print(f"baseline full-length x1: {base_qps:.1f} qps, "
          f"waste {base_stats.waste_ratio() * 100:.1f}%, "
          f"p95 {percentile(base_stats.latency_ms, 95):.2f} ms")

    rows = []
    by = {}
    for replicas in (1, 2, 4):
        for mode, continuous in (("batch", False), ("cont", True)):
            qps, stats = run_config(
                workload, replicas, bucketed=True, continuous=continuous
            )
            by[(mode, replicas)] = (qps, percentile(stats.latency_ms, 95))
            rows.append(row(mode, replicas, qps, stats))
            print(
                f"{mode} x{replicas}: {qps:.1f} qps, fill {stats.mean_fill():.2f}, "
                f"waste {stats.waste_ratio() * 100:.1f}%, "
                f"occup {stats.mean_occupancy():.2f}, "
                f"saved {stats.early_exit_ratio() * 100:.1f}%, "
                f"p50 {percentile(stats.latency_ms, 50):.2f} ms, "
                f"p95 {percentile(stats.latency_ms, 95):.2f} ms"
            )

    bq1, bp1 = by[("batch", 1)]
    cq1, cp1 = by[("cont", 1)]
    cq4, _ = by[("cont", 4)]
    qps_ratio = cq1 / bq1 if bq1 else 0.0
    p95_red = 1.0 - cp1 / bp1 if bp1 else 0.0
    print(f"continuous vs batch @x1: {qps_ratio:.2f}x qps, "
          f"p95 {bp1:.2f} -> {cp1:.2f} ms ({p95_red * 100:.1f}% lower), "
          f"cont scaling x4/x1 = {cq4 / cq1 if cq1 else 0.0:.2f}x")

    # §L7 degraded-mode A/B: cont x4 with replica KILL_REPLICA killed at
    # engine call KILL_AFTER, vs the healthy cont x4 just measured. The
    # supervisor requeues the in-flight work, respawns a replacement,
    # and every request stays terminal; acceptance bar: ratio >= 0.65.
    fault = {"kill_replica": KILL_REPLICA, "kill_after_calls": KILL_AFTER}
    dq, dstats = run_config(workload, 4, bucketed=True, continuous=True, fault=fault)
    dratio = dq / cq4 if cq4 else 0.0
    print(
        f"degraded cont x4 (replica {KILL_REPLICA} killed at call {KILL_AFTER}): "
        f"{dq:.1f} qps = {dratio:.2f}x of healthy, {dstats.retries} retried, "
        f"{dstats.restarts} restarts, {dstats.failed} failed, "
        f"terminal {dstats.requests + dstats.failed}/{len(workload)}"
    )

    # §L8 spec-vs-plain A/B: cont x1 with γ-draft/verify speculation vs
    # cont x1 plain, on a decode-heavy dec_len=128 variant of the same
    # prompt stream (generation dominates — the regime speculative
    # decoding targets). Decode-token throughput (tokens/s) is the
    # comparison; acceptance bar: >= 1.4x at the default accept coin.
    # 2x the grid's request count (an A/B over ~2 s runs sits inside
    # the scheduler-noise floor of a small shared host) and best-of-2
    # per arm (mirrors the bench): decode is deterministic — identical
    # tokens every trial — so trial spread is pure one-sided scheduler
    # noise and the faster trial is the better estimate.
    spec_requests = REQUESTS * 2
    spec_workload = mixed_prompts(spec_requests, ENC_LEN, VOCAB, 0x5E0A11)

    def best_of(n, gamma):
        best = None
        for _ in range(n):
            q, s = run_config(spec_workload, 1, bucketed=True, continuous=True,
                              dec_len=SPEC_DEC_LEN, gamma=gamma)
            if best is None or q > best[0]:
                best = (q, s)
        return best

    # §L9 paged A/B #1: slots-per-replica at equal pool memory. A
    # monolithic slot reserves the full enc+dec KV (pages_per_slot
    # pages); the paged engine allocates per request's actual bucket,
    # so the same pool hosts ~2x the concurrent slots on the mixed
    # workload. Prefix cache off: pure paging under test. Bar: best
    # occupancy ratio >= 1.5x.
    pages_per_slot = pages_for(ENC_LEN + DEC_LEN, PAGE_SIZE)
    paged_pairs = []
    best_slots_ratio = 0.0
    for mono_slots, paged_slots in ((2, 4), (4, 8), (8, 16)):
        pool_pages = pages_per_slot * mono_slots
        mq, ms = run_config(workload, 1, bucketed=True, continuous=True,
                            slots=mono_slots)
        pcfg = {"page_size": PAGE_SIZE, "pool_pages": pool_pages,
                "prefix_cache": False}
        gq, gs = run_config(workload, 1, bucketed=True, continuous=True,
                            slots=paged_slots, paged=pcfg)
        assert ms.tokens_generated == gs.tokens_generated, (
            ms.tokens_generated, gs.tokens_generated)
        ratio = gs.mean_occupancy() / ms.mean_occupancy() if ms.mean_occupancy() else 0.0
        best_slots_ratio = max(best_slots_ratio, ratio)
        print(
            f"paged pool={pool_pages}p: mono x{mono_slots} slots occup "
            f"{ms.mean_occupancy():.2f} ({mq:.1f} qps) vs paged x{paged_slots} "
            f"slots occup {gs.mean_occupancy():.2f} ({gq:.1f} qps) "
            f"= {ratio:.2f}x slots, {gs.alloc_stalls} stalls"
        )
        paged_pairs.append({
            "pool_pages": pool_pages,
            "monolithic_slots": mono_slots,
            "paged_slots": paged_slots,
            "monolithic": row("cont-mono", 1, mq, ms),
            "paged": row("cont-paged", 1, gq, gs),
            "slots_ratio": round(ratio, 3),
            "qps_ratio": round(gq / mq if mq else 0.0, 3),
        })
    assert best_slots_ratio >= 1.5, best_slots_ratio

    # §L9 paged A/B #2: tenant-skewed shared-prefix workload (4 system
    # prompts of 96 tokens = 6 full pages + short distinct tails).
    # Paged + prefix cache vs unpaged monolithic at the same slot
    # count: identical generated tokens, >= 40% of prefill tokens
    # saved by mapping cached header pages instead of re-running them.
    prefix_workload = shared_prefix_prompts(
        REQUESTS, ENC_LEN, VOCAB, 0x5E0A11, PREFIX_TENANTS, PREFIX_HEADER
    )
    uq, us = run_config(prefix_workload, 1, bucketed=True, continuous=True,
                        slots=PREFIX_SLOTS)
    pcfg = {"page_size": PAGE_SIZE, "pool_pages": PREFIX_POOL_PAGES,
            "prefix_cache": True}
    fq, fs = run_config(prefix_workload, 1, bucketed=True, continuous=True,
                        slots=PREFIX_SLOTS, paged=pcfg)
    assert us.tokens_generated == fs.tokens_generated, (
        us.tokens_generated, fs.tokens_generated)
    saved_ratio = fs.prefill_tokens_saved / max(
        fs.prefill_tokens_saved + fs.executed_tokens, 1
    )
    assert saved_ratio >= 0.40, saved_ratio
    assert fs.prefix_hit_rate() > 0.0
    print(
        f"prefix cache ({PREFIX_TENANTS} tenants, {PREFIX_HEADER}-token headers): "
        f"{saved_ratio * 100:.1f}% prefill tokens saved, "
        f"hit rate {fs.prefix_hit_rate() * 100:.1f}%, "
        f"{fs.evictions} evictions, {fq / uq if uq else 0.0:.2f}x qps vs unpaged, "
        f"tokens {fs.tokens_generated} == {us.tokens_generated}"
    )

    pq, pstats = best_of(2, 0)
    sq, sstats = best_of(2, SPEC_GAMMA)
    assert pstats.tokens_generated == sstats.tokens_generated, (
        pstats.tokens_generated, sstats.tokens_generated)
    plain_tps = pq * pstats.tokens_generated / spec_requests
    spec_tps = sq * sstats.tokens_generated / spec_requests
    tokens_ratio = spec_tps / plain_tps if plain_tps else 0.0
    print(
        f"speculative g={SPEC_GAMMA} (accept coin {ACCEPT_RATE}): "
        f"{tokens_ratio:.2f}x decode-token throughput "
        f"({spec_tps:.0f} vs {plain_tps:.0f} tok/s), "
        f"acceptance {sstats.acceptance_rate() * 100:.1f}%, "
        f"{sstats.tokens_per_verify():.2f} tokens/verify "
        f"over {sstats.verify_steps} verify steps"
    )

    # §L10 QoS + chaos A/B on the checked-in burst trace. Offered load
    # is ~4x the cont-x2 capacity just measured, so replay IS overload:
    #   clean  — QoS on, no chaos: the baseline the goodput bar is
    #            measured against.
    #   chaos  — QoS on, replica 1 killed at engine call QOS_KILL_CALL
    #            with 25% of the page pool withheld: admission sheds the
    #            free class at the door, the ladder autoscales, gold
    #            stays inside its 1.5 s SLO.
    #   off    — same chaos, QoS off (FIFO admission): gold waits behind
    #            the free flood and its p95 collapses — the contrast the
    #            layer exists for.
    trace = load_trace(QOS_TRACE, VOCAB)
    trace_span = max(trace[-1][0] / 1e6, 1e-9)
    qos_paged = {"page_size": 16, "pool_pages": QOS_POOL_PAGES,
                 "prefix_cache": False}
    chaos_paged = dict(qos_paged)
    chaos_paged["pool_pages"] = max(
        int(QOS_POOL_PAGES * (1 - QOS_POOL_RESERVE)),
        pages_for(ENC_LEN + DEC_LEN, qos_paged["page_size"]),
    )
    chaos = {"kill_replica": 1, "kill_after_calls": QOS_KILL_CALL}
    hq, hstats = run_config(trace, 2, bucketed=True, continuous=True,
                            paged=qos_paged, trace_mode=True,
                            tenants=QOS_TENANTS, autoscale=QOS_AUTOSCALE,
                            queue_cap=QOS_QUEUE_CAP)
    aq, astats = run_config(trace, 2, bucketed=True, continuous=True,
                            paged=chaos_paged, fault=chaos, trace_mode=True,
                            tenants=QOS_TENANTS, autoscale=QOS_AUTOSCALE,
                            queue_cap=QOS_QUEUE_CAP)
    oq, ostats = run_config(trace, 2, bucketed=True, continuous=True,
                            paged=chaos_paged, fault=chaos, trace_mode=True)

    def tmeter_of(stats_, t):
        return stats_.tenant_meters.get(t, stats_.tmeter(t))

    def goodput(stats_):
        return sum(m["slo_hits"] for m in stats_.tenant_meters.values())

    def tenant_rows(stats_):
        out = []
        for i in sorted(stats_.tenant_meters):
            m = stats_.tenant_meters[i]
            name = QOS_TENANTS[i]["name"] if i < len(QOS_TENANTS) else f"tenant-{i}"
            done = m["requests"] + m["failed"]
            out.append({
                "tenant": name,
                "requests": m["requests"],
                "failed": m["failed"],
                "sheds": m["sheds"],
                "slo_hits": m["slo_hits"],
                "goodput_ratio": round(m["slo_hits"] / done if done else 0.0, 4),
                "p50_ms": round(percentile(m["lat_ms"], 50), 2),
                "p95_ms": round(percentile(m["lat_ms"], 95), 2),
                "tokens_generated": m["tokens_generated"],
            })
        return out

    def qos_run_row(qps_, stats_):
        return {
            "qps": round(qps_, 1),
            "requests": stats_.requests,
            "failed": stats_.failed,
            "sheds": stats_.sheds,
            "retries": stats_.retries,
            "restarts": stats_.restarts,
            "terminal": stats_.requests + stats_.failed,
            "goodput": goodput(stats_),
            "tenants": tenant_rows(stats_),
        }

    gold_slo = QOS_TENANTS[2]["slo_ms"]
    a_gold = tmeter_of(astats, 2)
    gold_p95 = percentile(a_gold["lat_ms"], 95)
    free_shed_share = tmeter_of(astats, 0)["sheds"] / max(astats.sheds, 1)
    gp_ratio = goodput(astats) / max(goodput(hstats), 1)
    o_gold = tmeter_of(ostats, 2)
    o_gold_p95 = percentile(o_gold["lat_ms"], 95)
    cq2 = by[("cont", 2)][0]
    print(
        f"qos chaos (kill r1@call {QOS_KILL_CALL}, pool -{QOS_POOL_RESERVE*100:.0f}%): "
        f"{astats.sheds} sheds ({free_shed_share * 100:.1f}% free class), "
        f"gold p95 {gold_p95:.0f} ms (slo {gold_slo}), "
        f"goodput {goodput(astats)} = {gp_ratio:.2f}x clean, "
        f"{astats.restarts} restarts, "
        f"terminal {astats.requests + astats.failed}/{len(trace)}"
    )
    print(
        f"qos off, same chaos: gold p95 {o_gold_p95:.0f} ms, "
        f"{ostats.sheds} sheds — every class queues FIFO behind the flood"
    )
    # §L10 acceptance bars (mirror the bench's ensure! block).
    assert gold_p95 <= gold_slo, (gold_p95, gold_slo)
    assert free_shed_share >= 0.80, free_shed_share
    assert gp_ratio >= 0.8, gp_ratio
    assert o_gold["sheds"] > 0 or o_gold_p95 > gold_slo, (
        o_gold["sheds"], o_gold_p95,
    )

    # §L11 rolling-swap A/B on the same burst trace (mirrors the bench
    # swap section): no-swap baseline, clean rolling upgrade, rolling
    # upgrade + replica 1 killed mid-rollout, and a wrong-token bad
    # version that must fail the canary's parity probe and roll back.
    swap_at_s = trace_span * 0.25
    sw_clean = run_swap_trace(trace)
    sw_roll = run_swap_trace(trace, swap_salt=0)
    sw_chaos = run_swap_trace(
        trace, swap_salt=0,
        fault={"kill_replica": 1, "kill_after_calls": SWAP_KILL_CALL})
    sw_bad = run_swap_trace(trace, swap_salt=BAD_VERSION_SALT)

    def sw_ratio(run):
        return run[0] / sw_clean[0] if sw_clean[0] > 0 else 0.0

    print(
        f"swap trace ({len(trace)} reqs over {trace_span:.2f}s, rollout at "
        f"{swap_at_s:.2f}s): no-swap {sw_clean[0]:.1f} qps | rolling "
        f"{sw_roll[0]:.1f} qps ({sw_ratio(sw_roll):.2f}x) -> "
        f"{swap_status_str(sw_roll[3])} | +kill@{SWAP_KILL_CALL} "
        f"{sw_chaos[0]:.1f} qps ({sw_ratio(sw_chaos):.2f}x) -> "
        f"{swap_status_str(sw_chaos[3])} | bad-version -> "
        f"{swap_status_str(sw_bad[3])}"
    )
    print(
        f"swap ledger: rolling v-requests "
        f"{[sw_roll[2]['versions'][v]['requests'] for v in sorted(sw_roll[2]['versions'])]} "
        f"({sw_roll[2]['canary_pass']} canary pass) | chaos v-requests "
        f"{[sw_chaos[2]['versions'][v]['requests'] for v in sorted(sw_chaos[2]['versions'])]} "
        f"({sw_chaos[1].restarts} restarts) | bad rollbacks "
        f"{sw_bad[2]['rollbacks']} ({sw_bad[2]['canary_fail']} canary fail), "
        f"parity {sw_bad[4] == sw_clean[4]}"
    )
    # §L11 acceptance bars (mirror the bench's ensure! block).
    assert sw_roll[3]["state"] == "completed", sw_roll[3]
    assert sw_chaos[3]["state"] == "completed", sw_chaos[3]
    assert sw_bad[3]["state"] == "rolled_back", sw_bad[3]
    assert sw_bad[2]["rollbacks"] >= 1 and sw_bad[2]["canary_pass"] == 0, (
        sw_bad[2],
    )
    assert sw_roll[4] == sw_clean[4], (sw_roll[4], sw_clean[4])
    assert sw_chaos[4] == sw_clean[4], (sw_chaos[4], sw_clean[4])
    assert sw_bad[4] == sw_clean[4], (sw_bad[4], sw_clean[4])
    assert sw_roll[1].failed == 0, sw_roll[1].failed
    assert sw_chaos[1].failed == 0, sw_chaos[1].failed
    assert sw_ratio(sw_roll) >= 0.85, sw_ratio(sw_roll)
    assert sw_ratio(sw_chaos) >= 0.85, sw_ratio(sw_chaos)

    def swap_arm_row(run):
        qps_, stats_, dep, st, th = run
        vs = sorted(dep["versions"])
        return {
            "qps": round(qps_, 1),
            "requests": stats_.requests,
            "failed": stats_.failed,
            "sheds": stats_.sheds,
            "retries": stats_.retries,
            "restarts": stats_.restarts,
            "terminal": stats_.requests + stats_.failed,
            "status": swap_status_str(st),
            "canary_pass": dep["canary_pass"],
            "canary_fail": dep["canary_fail"],
            "rollbacks": dep["rollbacks"],
            "completed": dep["completed"],
            "aborted": dep["aborted"],
            "token_hash": f"{th:016x}",
            "version_requests": [dep["versions"][v]["requests"] for v in vs],
            "version_failed": [dep["versions"][v]["failed"] for v in vs],
        }

    # §L12 equal-device TP-vs-DP crossover A/B (mirrors the bench's tp
    # section). One TP-way execution group (replicas=1, tp=TP → TP
    # devices) against TP whole-model DP replicas (replicas=TP, tp=0 →
    # TP devices) at two load levels: the full client pool (peak —
    # DP's independent step streams win QPS) and a single closed-loop
    # client (light — one request in flight at a time, so the arms
    # compare pure per-request service time; the fused step runs the
    # full static slot geometry, so per-step speed is all that matters
    # and the group's sharded compute wins p95 while collectives stay
    # cheaper than the compute they shave). A single light client also
    # keeps exactly one cost-spinning replica thread alive at a time —
    # with concurrent spinners the GIL serializes the DP arm's two
    # replicas into a latency tax the one-thread TP group never pays,
    # which would hand TP the light arm for the wrong reason.
    # The 2x2 grid crosses AltUp's narrow active block
    # (payload d_model/4 per token) against a dense-widened baseline
    # (payload d_model) on a fast and a constrained link.
    def tp_coll(active_width, link_gbps):
        return {
            "active_width": active_width,
            "elem_bytes": TP_ELEM_BYTES,
            "link_gbps": link_gbps,
            "latency_ns": TP_LATENCY_NS,
            "syncs_per_step": TP_SYNCS_PER_STEP,
            "partitioned_frac": TP_PARTITIONED_FRAC,
        }

    tp_full = REQUESTS >= 256
    lat_n = min(max(REQUESTS // 2, TP_LIGHT_CLIENTS), len(workload))
    lworkload = workload[:lat_n]
    # Whole-model single-device references: the token-parity oracle
    # for every arm (sharding changes timing, never tokens) and the
    # 1-device latency baseline.
    rq, rstats = run_config(workload, 1, bucketed=True, continuous=True,
                            sleepy=True)
    lrq, lrstats = run_config(lworkload, 1, bucketed=True, continuous=True,
                              clients=TP_LIGHT_CLIENTS)

    tp_points = []
    tp_by = {}
    for pname, active_width, link_gbps in (
        ("altup-25g", TP_DMODEL // 4, 25.0),
        ("dense-25g", TP_DMODEL, 25.0),
        ("altup-2g", TP_DMODEL // 4, 2.0),
        ("dense-2g", TP_DMODEL, 2.0),
    ):
        coll = tp_coll(active_width, link_gbps)
        tpq, tps = run_config(workload, 1, bucketed=True, continuous=True,
                              tp=TP, collective=coll, sleepy=True)
        dpq, dps = run_config(workload, TP, bucketed=True, continuous=True,
                              sleepy=True)
        tlq, tls = run_config(lworkload, 1, bucketed=True, continuous=True,
                              clients=TP_LIGHT_CLIENTS, tp=TP, collective=coll)
        dlq, dls = run_config(lworkload, TP, bucketed=True, continuous=True,
                              clients=TP_LIGHT_CLIENTS)
        assert tps.tokens_generated == rstats.tokens_generated, (
            pname, tps.tokens_generated, rstats.tokens_generated)
        assert dps.tokens_generated == rstats.tokens_generated, (
            pname, dps.tokens_generated, rstats.tokens_generated)
        assert tls.tokens_generated == lrstats.tokens_generated, (
            pname, tls.tokens_generated, lrstats.tokens_generated)
        assert dls.tokens_generated == lrstats.tokens_generated, (
            pname, dls.tokens_generated, lrstats.tokens_generated)
        assert tps.devices == dps.devices, (pname, tps.devices, dps.devices)
        assert tps.collectives > 0 and dps.collectives == 0, (
            pname, tps.collectives, dps.collectives)
        mean_ar = tps.collective_ns / max(tps.collectives, 1)
        tp_p95 = percentile(tls.latency_ms, 95)
        dp_p95 = percentile(dls.latency_ms, 95)
        print(
            f"tp{TP}-{pname}: peak {tpq:.1f} vs dp {dpq:.1f} qps | light p95 "
            f"{tp_p95:.2f} vs dp {dp_p95:.2f} ms | allreduce {mean_ar / 1e3:.1f} us"
        )
        tp_by[pname] = (tpq, dpq, tp_p95, dp_p95, mean_ar)
        tp_points.append({
            "point": pname,
            "active_width": active_width,
            "link_gbps": link_gbps,
            "tp_peak": row("cont-tp", 1, tpq, tps),
            "dp_peak": row("cont-dp", TP, dpq, dps),
            "tp_light": row("cont-tp", 1, tlq, tls),
            "dp_light": row("cont-dp", TP, dlq, dls),
            "peak_qps_dp_over_tp": round(dpq / tpq if tpq else 0.0, 3),
            "light_p95_tp_over_dp": round(tp_p95 / dp_p95 if dp_p95 else 0.0, 3),
            "mean_allreduce_ns": round(mean_ar, 1),
        })

    cross = tp_by["altup-25g"]
    altup_slow = tp_by["altup-2g"]
    dense_slow = tp_by["dense-2g"]
    print(
        f"tp{TP} crossover @altup-25g: light p95 dp {cross[3]:.2f} -> tp "
        f"{cross[2]:.2f} ms | peak tp {cross[0]:.1f} vs dp {cross[1]:.1f} qps | "
        f"slow-link p95 ratio altup {altup_slow[2] / max(altup_slow[3], 1e-9):.2f} "
        f"dense {dense_slow[2] / max(dense_slow[3], 1e-9):.2f} | allreduce "
        f"{altup_slow[4] / 1e3:.1f} vs {dense_slow[4] / 1e3:.1f} us"
    )
    if tp_full:
        # §L12 acceptance bars (mirror the bench's ensure! block).
        assert cross[1] > cross[0], ("dp peak qps", cross[1], cross[0])
        assert cross[2] < cross[3], ("tp light p95", cross[2], cross[3])
        assert altup_slow[2] < altup_slow[3], (
            "altup slow link", altup_slow[2], altup_slow[3])
        assert dense_slow[2] > dense_slow[3], (
            "dense slow link", dense_slow[2], dense_slow[3])
        assert altup_slow[4] < 0.7 * dense_slow[4], (
            "allreduce payload", altup_slow[4], dense_slow[4])

    # Shard-kill chaos arm: one shard of the only group dies mid-run
    # (the group thread IS the tp-way lockstep unit, so a shard kill
    # is a group kill); §L7 requeues the in-flight work once, respawns
    # a full group, and token parity holds through the restart.
    tcq, tcs = run_config(
        workload, 1, bucketed=True, continuous=True, tp=TP,
        collective=tp_coll(TP_DMODEL // 4, 25.0), sleepy=True,
        fault={"kill_replica": 0, "kill_after_calls": TP_KILL_CALL})
    print(
        f"tp{TP} shard-kill@{TP_KILL_CALL}: {tcs.retries} requeued, "
        f"{tcs.restarts} restarts, {tcs.failed} failed, devices {tcs.devices} "
        f"(respawn re-counts the group), parity "
        f"{tcs.tokens_generated == rstats.tokens_generated}"
    )
    assert tcs.restarts >= 1, tcs.restarts
    assert tcs.retries >= 1, tcs.retries
    if tp_full:
        assert tcs.failed == 0, tcs.failed
        assert tcs.tokens_generated == rstats.tokens_generated, (
            tcs.tokens_generated, rstats.tokens_generated)

    # §L13 span-trace A/Bs (mirror of the bench's trace section): (a)
    # tracing-on vs tracing-off QPS on the closed-loop cont x2 workload
    # (best-of-2 per arm — mark recording must be ~free); (b) the burst
    # trace replayed healthy QoS-on vs QoS-off at full tracing, every
    # request's e2e attributed to the five top-level phases (the shares
    # sum to 1.0 by the tiling invariant); (c) a tp2 slow-link pair
    # where the narrow AltUp payload puts a smaller allreduce share of
    # engine time on the wire than the dense payload.
    def best_traced(with_tracer):
        best = None
        for _ in range(2):
            tr = new_tracer() if with_tracer else None
            q, s = run_config(workload, 2, bucketed=True, continuous=True,
                              tracer=tr)
            if best is None or q > best[0]:
                best = (q, s, tr)
        return best

    toff_q, _, _ = best_traced(False)
    ton_q, _, ton_tr = best_traced(True)
    overhead_ratio = ton_q / toff_q if toff_q else 0.0
    print(f"trace overhead: off {toff_q:.1f} qps, on {ton_q:.1f} qps "
          f"({overhead_ratio:.3f}x, {trace_span_count(ton_tr)} spans)")
    assert overhead_ratio >= 0.97, overhead_ratio

    qtr_on = new_tracer()
    qtr_off = new_tracer()
    tq_on, _ = run_config(trace, 2, bucketed=True, continuous=True,
                          paged=qos_paged, trace_mode=True,
                          tenants=QOS_TENANTS, autoscale=QOS_AUTOSCALE,
                          queue_cap=QOS_QUEUE_CAP, tracer=qtr_on)
    tq_off, _ = run_config(trace, 2, bucketed=True, continuous=True,
                           paged=qos_paged, trace_mode=True, tracer=qtr_off)

    def trace_arm(label, qps_, tr):
        attrs = trace_attrs(tr)
        assert attrs, label
        all_a = trace_attribute(attrs, 1.0)
        tail = trace_attribute(attrs, 0.05)
        # Top-level shares sum to 1.0 by construction (the phase
        # boundaries telescope); a zero total would mean no request
        # ever closed a phase.
        assert sum(all_a["phases"].values()) > 0.0, label
        lad = tr["ladder"]
        esc = sum(1 for i, (_, lv) in enumerate(lad)
                  if lv > (lad[i - 1][1] if i else 0))
        mean_ms = all_a["e2e_s"] / max(all_a["requests"], 1) * 1e3
        tail_ms = tail["e2e_s"] / max(tail["requests"], 1) * 1e3
        print(f"trace {label}: {qps_:.1f} qps, {all_a['requests']} attributed, "
              f"mean e2e {mean_ms:.1f} ms, slowest-5% {tail_ms:.1f} ms, "
              f"{esc} ladder escalations")
        return {
            "qps": round(qps_, 1),
            "requests_attributed": all_a["requests"],
            "dropped_spans": 0,
            "ladder_escalations": esc,
            "mean_e2e_ms": round(mean_ms, 2),
            "tail_e2e_ms": round(tail_ms, 2),
            "shares_all": trace_shares(all_a),
            "shares_tail_p95": trace_shares(tail),
        }, tail

    ta_on, tail_on = trace_arm("qos-on", tq_on, qtr_on)
    ta_off, tail_off = trace_arm("qos-off", tq_off, qtr_off)

    def queue_share(tail):
        sh = trace_shares(tail)
        return (sh["admission-queue"] + sh["qos-queue"]
                + sh["router-dispatch"])

    print(f"trace tail queue-wait share (admission+qos+dispatch): "
          f"qos-on {queue_share(tail_on) * 100:.0f}%, "
          f"qos-off {queue_share(tail_off) * 100:.0f}%")

    trn = new_tracer()
    trd = new_tracer()
    tnq, tns = run_config(workload, 1, bucketed=True, continuous=True, tp=TP,
                          collective=tp_coll(TP_DMODEL // 4, 2.0), sleepy=True,
                          tracer=trn)
    tdq, tds = run_config(workload, 1, bucketed=True, continuous=True, tp=TP,
                          collective=tp_coll(TP_DMODEL, 2.0), sleepy=True,
                          tracer=trd)

    def ar_share(stats_, tr):
        eng = tr["phase_ns"]["prefill"] + tr["phase_ns"]["decode-iteration"]
        return stats_.collective_ns / max(eng, 1)

    share_n = ar_share(tns, trn)
    share_d = ar_share(tds, trd)
    assert tns.collectives > 0 and tds.collectives > 0
    # §L13 acceptance bar (mirrors the bench's ensure!): the narrow
    # active block's sync is a smaller share of engine time.
    assert share_n < share_d, (share_n, share_d)
    print(f"trace tp{TP}@2g allreduce share of engine time: "
          f"altup {share_n * 100:.1f}% vs dense {share_d * 100:.1f}% "
          f"({tnq:.1f} vs {tdq:.1f} qps)")

    trace_doc = {
        "sample": 1.0,
        "bars_enforced": True,
        "overhead": {
            "qps_off": round(toff_q, 1),
            "qps_on": round(ton_q, 1),
            "ratio_on_over_off": round(overhead_ratio, 3),
            "spans_recorded": trace_span_count(ton_tr),
            "dropped_spans": 0,
        },
        "qos_on": ta_on,
        "qos_off": ta_off,
        "tail_queue_wait_share": {
            "qos_on": round(queue_share(tail_on), 4),
            "qos_off": round(queue_share(tail_off), 4),
        },
        "tp_slow_link": {
            "tp": TP,
            "d_model": TP_DMODEL,
            "narrow_active_width": TP_DMODEL // 4,
            "link_gbps": 2.0,
            "qps_narrow": round(tnq, 1),
            "qps_dense": round(tdq, 1),
            "allreduce_share_narrow": round(share_n, 4),
            "allreduce_share_dense": round(share_d, 4),
        },
    }

    tp_doc = {
        "tp": TP,
        "d_model": TP_DMODEL,
        "elem_bytes": TP_ELEM_BYTES,
        "latency_ns": TP_LATENCY_NS,
        "syncs_per_step": TP_SYNCS_PER_STEP,
        "partitioned_frac": TP_PARTITIONED_FRAC,
        "clients_peak": CLIENTS,
        "clients_light": TP_LIGHT_CLIENTS,
        "requests_light": lat_n,
        "bars_enforced": tp_full,
        "single_reference_peak": row("cont-single", 1, rq, rstats),
        "single_reference_light": row("cont-single", 1, lrq, lrstats),
        "points": tp_points,
        "crossover": {
            "point": "altup-25g",
            "dp_wins_peak_qps": cross[1] > cross[0],
            "tp_wins_light_p95": cross[2] < cross[3],
        },
        "slow_link": {
            "altup_point": "altup-2g",
            "dense_point": "dense-2g",
            "tp_still_ahead_on_altup": altup_slow[2] < altup_slow[3],
            "tp_behind_on_dense": dense_slow[2] > dense_slow[3],
            "mean_allreduce_ratio_altup_over_dense": round(
                altup_slow[4] / max(dense_slow[4], 1e-9), 3),
        },
        "chaos": {
            "kill_shard": 1,
            "kill_at_call": TP_KILL_CALL,
            "qps": round(tcq, 1),
            "requests": tcs.requests,
            "failed": tcs.failed,
            "retries": tcs.retries,
            "restarts": tcs.restarts,
            "devices": tcs.devices,
            "token_parity": tcs.tokens_generated == rstats.tokens_generated,
        },
    }

    doc = {
        "bench": "server_throughput",
        "engine": "sim",
        "workload": {
            "requests": REQUESTS,
            "clients": CLIENTS,
            "batch_size": BATCH_SIZE,
            "enc_len": ENC_LEN,
            "dec_len": DEC_LEN,
            "slots": 0,
            "mix": "70% short [4, enc/4), 30% long [enc/2, enc)",
            "eos": "generation length hash-sampled uniform in [1, dec_len]",
            "batch_window_ms": WINDOW_S * 1e3,
        },
        "baseline_full_length": row("batch-unbucketed", 1, base_qps, base_stats),
        "configs": rows,
        "cont_over_batch_x1": {
            "qps_ratio": round(qps_ratio, 3),
            "p95_reduction": round(p95_red, 3),
        },
        "qps_scaling_x4_over_x1": round(cq4 / cq1 if cq1 else 0.0, 3),
        "degraded": {
            "kill_replica": KILL_REPLICA,
            "kill_after_calls": KILL_AFTER,
            "healthy_qps": round(cq4, 1),
            "qps": round(dq, 1),
            "qps_ratio": round(dratio, 3),
            "retries": dstats.retries,
            "restarts": dstats.restarts,
            "sheds": dstats.sheds,
            "failed": dstats.failed,
            "terminal": dstats.requests + dstats.failed,
            "requests": REQUESTS,
        },
        "speculative": {
            "gamma": SPEC_GAMMA,
            "requests": spec_requests,
            "dec_len": SPEC_DEC_LEN,
            "accept_coin": ACCEPT_RATE,
            "plain": row("cont-plain", 1, pq, pstats),
            "spec": row("cont-spec", 1, sq, sstats),
            "plain_tokens_per_sec": round(plain_tps, 1),
            "spec_tokens_per_sec": round(spec_tps, 1),
            "tokens_ratio": round(tokens_ratio, 3),
            "acceptance_rate": round(sstats.acceptance_rate(), 4),
            "tokens_per_verify": round(sstats.tokens_per_verify(), 3),
            "drafted": sstats.drafted,
            "accepted": sstats.accepted,
            "verify_steps": sstats.verify_steps,
            "draft_steps": sstats.draft_steps,
        },
        "paged": {
            "page_size": PAGE_SIZE,
            "pages_per_slot": pages_per_slot,
            "pairs": paged_pairs,
            "slots_ratio": round(best_slots_ratio, 3),
        },
        "prefix": {
            "page_size": PAGE_SIZE,
            "tenants": PREFIX_TENANTS,
            "header_tokens": PREFIX_HEADER,
            "pool_pages": PREFIX_POOL_PAGES,
            "slots": PREFIX_SLOTS,
            "requests": REQUESTS,
            "unpaged": row("cont-mono", 1, uq, us),
            "paged": row("cont-prefix", 1, fq, fs),
            "prefill_saved_ratio": round(saved_ratio, 4),
            "prefix_hit_rate": round(fs.prefix_hit_rate(), 4),
            "qps_ratio": round(fq / uq if uq else 0.0, 3),
            "tokens_match": True,
        },
        "qos": {
            "trace": QOS_TRACE,
            "trace_requests": len(trace),
            "trace_span_s": round(trace_span, 3),
            "offered_qps": round(len(trace) / trace_span, 1),
            "capacity_qps_cont_x2": round(cq2, 1),
            "tenant_spec": QOS_TENANT_SPEC,
            "chaos_schedule": {
                "kill_replica": 1,
                "kill_at_call": QOS_KILL_CALL,
                "pool_reserve": QOS_POOL_RESERVE,
            },
            "bars_enforced": True,
            "qos_clean": qos_run_row(hq, hstats),
            "qos_chaos": qos_run_row(aq, astats),
            "qos_off_chaos": qos_run_row(oq, ostats),
            "goodput_ratio_chaos_over_clean": round(gp_ratio, 3),
            "free_shed_share": round(free_shed_share, 4),
            "gold_slo_ms": gold_slo,
            "gold_p95_ms_qos": round(gold_p95, 2),
            "gold_p95_ms_qos_off": round(o_gold_p95, 2),
        },
        "tp": tp_doc,
        "deploy": {
            "trace": QOS_TRACE,
            "trace_requests": len(trace),
            "trace_span_s": round(trace_span, 3),
            "swap_at_s": round(swap_at_s, 3),
            "cost_mult": SWAP_COST_MULT,
            "chaos_schedule": {
                "kill_replica": 1,
                "kill_at_call": SWAP_KILL_CALL,
            },
            "bars_enforced": True,
            "no_swap": swap_arm_row(sw_clean),
            "rolling": swap_arm_row(sw_roll),
            "rolling_chaos": swap_arm_row(sw_chaos),
            "bad_version": swap_arm_row(sw_bad),
            "goodput_ratio_rolling": round(sw_ratio(sw_roll), 3),
            "goodput_ratio_chaos": round(sw_ratio(sw_chaos), 3),
            "token_parity": {
                "rolling": sw_roll[4] == sw_clean[4],
                "rolling_chaos": sw_chaos[4] == sw_clean[4],
                "bad_version_rollback": sw_bad[4] == sw_clean[4],
            },
        },
        "trace": trace_doc,
        "producer": "python/tools/server_throughput_twin.py "
                    "(threaded twin; re-run `cargo bench --bench server_throughput -- --json` "
                    "on a cargo-enabled machine to overwrite with the Rust measurement)",
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
