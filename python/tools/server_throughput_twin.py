"""Threaded twin of `rust/benches/server_throughput.rs`.

Mirrors the Rust serving bench 1:1 — same SplitMix64 workload stream,
same bucket ladder (`runtime::session::bucket_for`), same router policy
(group by bucket, flush on full batch or expired window), same replica
pool semantics, and the same sim-decode cost model (sleep proportional
to the executed ``batch_size x bucket`` geometry) — so the serving-
policy numbers (QPS scaling across replicas, padded-token waste,
latency percentiles) can be measured on machines without a cargo
toolchain or a PJRT backend. The Rust bench is the canonical producer
of BENCH_server_throughput.json; running it overwrites this twin's
output (the ``producer`` field records which one wrote the file).

Usage: python3 python/tools/server_throughput_twin.py [out.json]
"""

import json
import queue
import sys
import threading
import time

MASK = (1 << 64) - 1

BATCH_SIZE = 8
ENC_LEN = 128
TOKEN_NS = 20000  # mirrors SimSpec::new's default
WINDOW_S = 0.002
REQUESTS = 384
CLIENTS = 32
MIN_BUCKET = 8


class Rng:
    """SplitMix64, matching rust/src/util/rng.rs bit-for-bit."""

    def __init__(self, seed):
        self.state = (seed + 0x9E3779B97F4A7C15) & MASK

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def range(self, lo, hi):
        return lo + ((self.next_u64() * (hi - lo)) >> 64)


def bucket_for(length, enc_len):
    """Mirror of runtime::session::bucket_for."""
    if length >= enc_len:
        return enc_len
    b = MIN_BUCKET
    while b < enc_len:
        if length <= b:
            return b
        b <<= 1
    return enc_len


def mixed_prompt_lengths(n, enc_len, seed):
    """Mirror of the bench's mixed_prompts draw order (length draw plus
    one RNG draw per token, so the stream stays aligned)."""
    rng = Rng(seed)
    lengths = []
    for _ in range(n):
        if rng.next_f64() < 0.7:
            length = rng.range(4, max(enc_len // 4, 5))
        else:
            length = rng.range(enc_len // 2, enc_len)
        for _ in range(length):
            rng.next_u64()  # token draw
        lengths.append(length)
    return lengths


def percentile(samples, p):
    if not samples:
        return 0.0
    v = sorted(samples)
    idx = round((p / 100.0) * (len(v) - 1))
    return v[min(idx, len(v) - 1)]


class Stats:
    def __init__(self):
        self.requests = 0
        self.batches = 0
        self.total_fill = 0
        self.prompt_tokens = 0
        self.executed_tokens = 0
        self.latency_ms = []
        self.lock = threading.Lock()

    def waste_ratio(self):
        if self.executed_tokens == 0:
            return 0.0
        return 1.0 - self.prompt_tokens / self.executed_tokens

    def mean_fill(self):
        return self.total_fill / self.batches if self.batches else 0.0


def run_config(lengths, replicas, bucketed):
    req_q = queue.Queue()
    # Bounded job queue = backpressure, mirroring the Rust router: full
    # groups ship with a blocking put; due-but-partial groups ship
    # best-effort and otherwise keep accumulating while replicas are
    # busy.
    job_q = queue.Queue(maxsize=max(replicas, 1))
    stats = Stats()
    n_clients = CLIENTS

    def router():
        # bucket -> list of (t0, admitted, reply_q, length); latency is
        # reported from the client-side t0, the batch-window deadline
        # runs from admission (mirrors the Rust router).
        groups = {}
        live_clients = n_clients
        disconnected = False
        while not (disconnected and not groups):
            # Flush pass.
            now = time.monotonic()
            due_unsent = False
            for bucket in list(groups.keys()):
                group = groups[bucket]
                full = len(group) >= BATCH_SIZE
                due = now >= group[0][1] + WINDOW_S
                if full or disconnected:
                    job_q.put((bucket, groups.pop(bucket)))
                elif due:
                    g = groups.pop(bucket)
                    try:
                        job_q.put_nowait((bucket, g))
                    except queue.Full:
                        groups[bucket] = g
                        due_unsent = True
            if disconnected:
                continue
            # Admit pass.
            msg = None
            if not groups:
                m = req_q.get()
                if m is None:
                    live_clients -= 1
                    if live_clients == 0:
                        disconnected = True
                else:
                    msg = m
            else:
                if due_unsent:
                    wait = WINDOW_S
                else:
                    oldest = min(g[0][1] for g in groups.values())
                    wait = oldest + WINDOW_S - time.monotonic()
                if wait > 0:
                    try:
                        m = req_q.get(timeout=wait)
                        if m is None:
                            live_clients -= 1
                            if live_clients == 0:
                                disconnected = True
                        else:
                            msg = m
                    except queue.Empty:
                        pass
            if msg is not None:
                t0, reply, length = msg
                bucket = bucket_for(length, ENC_LEN) if bucketed else ENC_LEN
                groups.setdefault(bucket, []).append(
                    (t0, time.monotonic(), reply, length)
                )
        for _ in range(max(replicas, 1)):
            job_q.put(None)

    def replica():
        while True:
            job = job_q.get()
            if job is None:
                break
            bucket, group = job
            time.sleep(TOKEN_NS * BATCH_SIZE * bucket / 1e9)  # sim decode
            now = time.monotonic()
            with stats.lock:
                stats.batches += 1
                stats.total_fill += len(group)
                stats.requests += len(group)
                stats.executed_tokens += BATCH_SIZE * bucket
                for t0, _admitted, _reply, length in group:
                    stats.prompt_tokens += min(length, bucket)
                    stats.latency_ms.append((now - t0) * 1e3)
            for _t0, _admitted, reply, _length in group:
                reply.put(True)

    def client(c):
        for length in lengths[c::n_clients]:
            reply = queue.SimpleQueue()
            req_q.put((time.monotonic(), reply, length))
            reply.get()
        req_q.put(None)  # this client is done

    threads = [threading.Thread(target=router, name="router")]
    threads += [
        threading.Thread(target=replica, name=f"replica-{i}")
        for i in range(max(replicas, 1))
    ]
    t_start = time.monotonic()
    client_threads = [
        threading.Thread(target=client, args=(c,), name=f"client-{c}")
        for c in range(n_clients)
    ]
    for t in threads + client_threads:
        t.start()
    for t in client_threads:
        t.join()
    for t in threads:
        t.join()
    wall = time.monotonic() - t_start
    qps = len(lengths) / max(wall, 1e-9)
    return qps, stats


def row(qps, stats, replicas=None):
    out = {}
    if replicas is not None:
        out["replicas"] = replicas
    out.update(
        {
            "qps": round(qps, 1),
            "mean_fill": round(stats.mean_fill(), 3),
            "waste_ratio": round(stats.waste_ratio(), 4),
            "prompt_tokens": stats.prompt_tokens,
            "executed_tokens": stats.executed_tokens,
            "batches": stats.batches,
            "p50_ms": round(percentile(stats.latency_ms, 50), 2),
            "p95_ms": round(percentile(stats.latency_ms, 95), 2),
            "p99_ms": round(percentile(stats.latency_ms, 99), 2),
        }
    )
    return out


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_server_throughput.json"
    lengths = mixed_prompt_lengths(REQUESTS, ENC_LEN, 0x5E0A11)

    base_qps, base_stats = run_config(lengths, replicas=1, bucketed=False)
    print(f"baseline full-length x1: {base_qps:.1f} qps, "
          f"waste {base_stats.waste_ratio() * 100:.1f}%")

    rows = []
    qps_by = {}
    for replicas in (1, 2, 4):
        qps, stats = run_config(lengths, replicas=replicas, bucketed=True)
        qps_by[replicas] = qps
        rows.append(row(qps, stats, replicas))
        print(f"bucketed x{replicas}: {qps:.1f} qps, fill {stats.mean_fill():.2f}, "
              f"waste {stats.waste_ratio() * 100:.1f}%, "
              f"p50 {percentile(stats.latency_ms, 50):.2f} ms")

    scaling = qps_by[4] / qps_by[1] if qps_by[1] else 0.0
    print(f"scaling x4/x1 = {scaling:.2f}x")

    doc = {
        "bench": "server_throughput",
        "engine": "sim",
        "workload": {
            "requests": REQUESTS,
            "clients": CLIENTS,
            "batch_size": BATCH_SIZE,
            "enc_len": ENC_LEN,
            "mix": "70% short [4, enc/4), 30% long [enc/2, enc)",
            "batch_window_ms": WINDOW_S * 1e3,
        },
        "baseline_full_length": row(base_qps, base_stats),
        "replicas": rows,
        "qps_scaling_x4_over_x1": round(scaling, 3),
        "producer": "python/tools/server_throughput_twin.py "
                    "(threaded twin; re-run `cargo bench --bench server_throughput -- --json` "
                    "on a cargo-enabled machine to overwrite with the Rust measurement)",
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
